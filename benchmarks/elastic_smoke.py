"""Elastic-fleet smoke: the CI gate for tentpole PR 9.

Spins up real ``launch/worker.py`` subprocesses against an in-process
``RemoteWorkerPool`` (the tuner side) and gates the elastic contract:

* **join** — a worker joining mid-run (``--join`` against the pool's
  always-open join socket) raises measured throughput: the same batch
  finishes in <= ``JOIN_SPEEDUP`` x the static-fleet wall clock;
* **speculation** — with one artificially-slowed worker in the fleet,
  speculative straggler re-execution finishes the batch in <=
  ``SPEC_SPEEDUP`` x the wall clock of the same fleet with speculation
  off;
* **exactly-once** — SIGKILLing the straggler host while its task has
  a live speculative duplicate loses 0 results and double-records 0;
* **strict homogeneity** — a fleet never mixes two distinct hardware
  fingerprints: a statically mis-assembled fleet fails construction and
  a mismatched joiner is turned away while the run continues.

Workers serve ``make_smoke_objective()`` from this module: value is a
deterministic function of the point, measurement time is
``BASE_SLEEP_S`` scaled by the ``ELASTIC_SMOKE_SLOWDOWN`` environment
variable (how the slow host is made slow), and the declared
``cost_seconds`` is hardware-independent so recorded traces stay
byte-comparable across fleets.

Usage (CI runs exactly this):

    PYTHONPATH=src:. python -m benchmarks.elastic_smoke --check \
        --out BENCH_elastic.json
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import time

BASE_SLEEP_S = 0.1
JOIN_BATCH = 20
JOIN_SPEEDUP = 0.85   # elastic wall / static wall must be <= this
SPEC_BATCH = 8
SPEC_SLOWDOWN = 25.0  # the slow host: 0.1s evals take 2.5s
SPEC_SPEEDUP = 0.6    # speculation-on wall / off wall must be <= this


def make_smoke_objective():
    """Deterministic objective whose measurement speed is per-*host*
    (``ELASTIC_SMOKE_SLOWDOWN`` env), not per-point — exactly the
    straggling-hardware shape speculation exists for."""
    slowdown = float(os.environ.get("ELASTIC_SMOKE_SLOWDOWN", "1.0"))

    def objective(p, fidelity=None):
        time.sleep(BASE_SLEEP_S * slowdown)
        return float(p["a"] * 10 + p["b"]), {"cost_seconds": BASE_SLEEP_S}

    objective.returns_meta = True  # the (value, meta) contract, declared
    return objective


def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _env(root: pathlib.Path, slowdown: float = 1.0) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root)]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    env["ELASTIC_SMOKE_SLOWDOWN"] = str(slowdown)
    return env


def spawn_worker(root: pathlib.Path, *, port=None, join=None, slots=1,
                 slowdown=1.0, tag=None) -> subprocess.Popen:
    cmd = [sys.executable, "-m", "repro.launch.worker",
           "--host", "127.0.0.1", "--slots", str(slots),
           "--heartbeat-s", "0.2", "--objective",
           "benchmarks.elastic_smoke:make_smoke_objective()"]
    if port is not None:
        cmd += ["--port", str(port)]
    if join is not None:
        cmd += ["--join", join, "--join-retry-s", "0.2"]
    if tag is not None:
        cmd += ["--fingerprint-tag", tag]
    return subprocess.Popen(cmd, env=_env(root, slowdown), cwd=str(root),
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)


def wait_port(port: int, timeout_s: float = 20.0) -> None:
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        try:
            socket.create_connection(("127.0.0.1", port), timeout=0.2).close()
            return
        except OSError:
            time.sleep(0.05)
    raise TimeoutError(f"worker on port {port} never came up")


def reap(*procs) -> None:
    for p in procs:
        if p is not None and p.poll() is None:
            p.terminate()
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()


def drive_batch(pool, n: int) -> float:
    """Submit n points, wait for every future; returns the wall clock."""
    t0 = time.perf_counter()
    futures = [pool.submit(None, None, {"a": i % 10, "b": i % 5})
               for i in range(n)]
    for i, f in enumerate(futures):
        value, _seconds, _meta = f.result(timeout=120)
        assert value == float((i % 10) * 10 + i % 5)
    return time.perf_counter() - t0


def local_join(pool) -> str:
    port = pool.join_address.rsplit(":", 1)[1]
    return f"127.0.0.1:{port}"


# ---------------------------------------------------------------------------
# gate (a): mid-run join raises throughput
# ---------------------------------------------------------------------------

def bench_join(root, emit) -> dict:
    from repro.tuning.remote import FleetOptions, RemoteWorkerPool

    fleet = FleetOptions(speculation=False)
    p1 = free_port()
    w1 = spawn_worker(root, port=p1)
    joiner = None
    try:
        wait_port(p1)
        # static: the startup fleet runs the whole batch
        pool = RemoteWorkerPool([f"127.0.0.1:{p1}"], fleet=fleet)
        static_wall = drive_batch(pool, JOIN_BATCH)
        pool.shutdown()
        # elastic: same batch, but a second daemon dials the join socket
        # mid-run and the pool puts its slots to work immediately
        pool = RemoteWorkerPool([f"127.0.0.1:{p1}"], fleet=fleet)
        joiner = spawn_worker(root, join=local_join(pool))
        elastic_wall = drive_batch(pool, JOIN_BATCH)
        joined = pool.parallelism  # capacity after the join
        pool.shutdown()
    finally:
        reap(w1, joiner)
    ratio = elastic_wall / static_wall
    emit(f"[elastic-smoke] join: static {static_wall:.2f}s vs elastic "
         f"{elastic_wall:.2f}s (ratio {ratio:.2f}, fleet grew to "
         f"{joined} slots)")
    return {"static_wall_s": round(static_wall, 3),
            "elastic_wall_s": round(elastic_wall, 3),
            "ratio": round(ratio, 3), "slots_after_join": joined,
            "ok": ratio <= JOIN_SPEEDUP and joined >= 2}


# ---------------------------------------------------------------------------
# gates (b) + (c): speculation wall clock and exactly-once under SIGKILL
# ---------------------------------------------------------------------------

def _spec_fleet(root):
    """One healthy 2-slot worker + one SPEC_SLOWDOWN-slowed worker."""
    p_slow, p_fast = free_port(), free_port()
    w_slow = spawn_worker(root, port=p_slow, slowdown=SPEC_SLOWDOWN)
    w_fast = spawn_worker(root, port=p_fast, slots=2)
    wait_port(p_slow)
    wait_port(p_fast)
    return w_slow, w_fast, [f"127.0.0.1:{p_slow}", f"127.0.0.1:{p_fast}"]


def bench_speculation(root, emit) -> dict:
    from repro.tuning.remote import FleetOptions, RemoteWorkerPool

    walls = {}
    for spec in (False, True):
        w_slow, w_fast, addrs = _spec_fleet(root)
        try:
            pool = RemoteWorkerPool(addrs, fleet=FleetOptions(
                speculation=spec, speculation_factor=2.0,
                min_observations=3))
            walls[spec] = drive_batch(pool, SPEC_BATCH)
            speculations = pool.speculations
            pool.shutdown()
        finally:
            reap(w_slow, w_fast)
    ratio = walls[True] / walls[False]
    emit(f"[elastic-smoke] speculation: off {walls[False]:.2f}s vs on "
         f"{walls[True]:.2f}s (ratio {ratio:.2f}, "
         f"{speculations} duplicates)")
    return {"off_wall_s": round(walls[False], 3),
            "on_wall_s": round(walls[True], 3),
            "ratio": round(ratio, 3), "speculations": speculations,
            "ok": ratio <= SPEC_SPEEDUP and speculations >= 1}


def bench_sigkill_exactly_once(root, emit) -> dict:
    from repro.tuning.remote import FleetOptions, RemoteWorkerPool

    w_slow, w_fast, addrs = _spec_fleet(root)
    try:
        pool = RemoteWorkerPool(addrs, fleet=FleetOptions(
            speculation=True, speculation_factor=2.0, min_observations=3))
        points = [{"a": i % 10, "b": i % 5} for i in range(SPEC_BATCH)]
        futures = [pool.submit(None, None, dict(p)) for p in points]
        # wait for a live duplicate, then SIGKILL the straggler host
        # while both copies are in flight
        deadline = time.time() + 60
        while pool.speculations < 1 and time.time() < deadline:
            time.sleep(0.02)
        assert pool.speculations >= 1, "speculation never triggered"
        w_slow.send_signal(signal.SIGKILL)
        w_slow.wait(timeout=10)
        results, lost = [], 0
        for f in futures:
            try:
                results.append(f.result(timeout=120))
            except Exception:  # a stranded future == a lost result
                lost += 1
                results.append(None)
        values_ok = all(
            r is not None and r[0] == float(p["a"] * 10 + p["b"])
            for r, p in zip(results, points))
        # one resolution per submission, none lost, none doubled: the
        # futures ARE the recording path (memo/corpus hang off them)
        stats = pool.fleet_stats()
        pool.shutdown()
    finally:
        reap(w_slow, w_fast)
    emit(f"[elastic-smoke] sigkill: {len(results)}/{SPEC_BATCH} results "
         f"after killing the straggler host "
         f"(speculations={stats['speculations']})")
    return {"results": len(results), "expected": SPEC_BATCH,
            "lost": lost, "values_ok": values_ok,
            "speculations": stats["speculations"],
            "ok": lost == 0 and values_ok and len(results) == SPEC_BATCH}


# ---------------------------------------------------------------------------
# gate (d): strict homogeneity never mixes fingerprints
# ---------------------------------------------------------------------------

def bench_strict_homogeneity(root, emit) -> dict:
    from repro.tuning.remote import FleetOptions, RemoteWorkerPool

    p1, p2 = free_port(), free_port()
    w1 = spawn_worker(root, port=p1, tag="partition-A")
    w2 = spawn_worker(root, port=p2, tag="partition-B")
    joiner = None
    try:
        wait_port(p1)
        wait_port(p2)
        static_refused = False
        try:
            RemoteWorkerPool([f"127.0.0.1:{p1}", f"127.0.0.1:{p2}"])
        except ConnectionError:
            static_refused = True  # mis-assembled fleet fails construction
        pool = RemoteWorkerPool([f"127.0.0.1:{p1}"])
        joiner = spawn_worker(root, join=local_join(pool),
                              tag="partition-B")
        deadline = time.time() + 30
        while pool.rejected_joins < 1 and time.time() < deadline:
            time.sleep(0.05)
        join_rejected = pool.rejected_joins >= 1
        survived = pool.parallelism == 1  # the pinned run goes on
        pool.shutdown()
    finally:
        reap(w1, w2, joiner)
    emit(f"[elastic-smoke] strict: static mix refused={static_refused}, "
         f"mismatched join rejected={join_rejected}")
    return {"static_refused": static_refused,
            "join_rejected": join_rejected, "run_survived": survived,
            "ok": static_refused and join_rejected and survived}


def run_smoke(emit=print) -> dict:
    root = pathlib.Path(__file__).resolve().parents[1]
    t0 = time.perf_counter()
    join = bench_join(root, emit)
    spec = bench_speculation(root, emit)
    sigkill = bench_sigkill_exactly_once(root, emit)
    strict = bench_strict_homogeneity(root, emit)
    gates = {
        "join_raises_throughput": join["ok"],
        "speculation_cuts_wall_clock": spec["ok"],
        "sigkill_loses_nothing": sigkill["ok"],
        "strict_never_mixes": strict["ok"],
    }
    return {"bench": "elastic_smoke",
            "base_sleep_s": BASE_SLEEP_S,
            "join_speedup_gate": JOIN_SPEEDUP,
            "spec_speedup_gate": SPEC_SPEEDUP,
            "wall_s": round(time.perf_counter() - t0, 3),
            "join": join, "speculation": spec, "sigkill": sigkill,
            "strict": strict, "gates": gates, "ok": all(gates.values())}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any gate fails")
    args = ap.parse_args(argv)

    result = run_smoke()
    print(json.dumps(result, indent=2))
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(result, indent=2))
        print(f"[elastic-smoke] wrote {args.out}")
    if args.check and not result["ok"]:
        failed = [g for g, ok in result["gates"].items() if not ok]
        print(f"[elastic-smoke] FAILED gates: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
