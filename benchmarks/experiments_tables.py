"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts (reads the restart-safe jsonl)."""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def load(path=None):
    path = pathlib.Path(path or (ART / "dryrun_all.json.jsonl"))
    recs = {}
    for line in path.read_text().splitlines():
        try:
            r = json.loads(line)
        except Exception:
            continue
        key = (r["arch"], r["shape"], bool(r.get("multi_pod")))
        recs[key] = r  # later lines win (reruns)
    return recs


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | status | mem/dev GB | collectives |",
            "|---|---|---|---|---|---|"]
    for (arch, shape, mp), r in sorted(recs.items()):
        mesh = "2x16x16" if mp else "16x16"
        if r.get("skipped"):
            rows.append(f"| {arch} | {shape} | {mesh} | SKIP ({r['skip_reason'][:40]}…) | — | — |")
        elif "error" in r:
            rows.append(f"| {arch} | {shape} | {mesh} | ERROR | — | — |")
        else:
            mem = r["memory"]["per_device_B"] / 1e9
            coll = r["roofline"]["collectives"]
            rows.append(f"| {arch} | {shape} | {mesh} | compiled | {mem:.2f} | {coll[:80]} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = [
        "| arch | shape | compute s | memory s (hlo-raw s) | coll s | bottleneck "
        "| step s | tok/s | MFU | useful | mem GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mp), r in sorted(recs.items()):
        if mp or r.get("skipped") or "error" in r:
            continue
        x = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {x['compute_s']:.3f} | {x['memory_s']:.3f} "
            f"({x['memory_s_hlo_raw']:.1f}) | {x['collective_s']:.3f} "
            f"| {x['bottleneck']} | {x['est_step_s']:.3f} "
            f"| {x['throughput_tok_s']:.3g} | {x['mfu']:.3f} "
            f"| {x['useful_flops_ratio']:.2f} | {x['mem_per_device_GB']:.1f} "
            f"| {x['fits_hbm']} |"
        )
    return "\n".join(rows)


def pick_hillclimb_cells(recs):
    """worst roofline fraction / most collective-bound / representative."""
    singles = {k: r for k, r in recs.items()
               if not k[2] and not r.get("skipped") and "error" not in r}
    frac = {k: r["roofline"]["roofline_fraction"] for k, r in singles.items()}
    coll_share = {
        k: r["roofline"]["collective_s"] / max(r["roofline"]["est_step_s"], 1e-12)
        for k, r in singles.items()
    }
    worst_frac = min(frac, key=frac.get)
    most_coll = max(coll_share, key=coll_share.get)
    return {"worst_roofline_fraction": worst_frac, "most_collective_bound": most_coll}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None)
    args = ap.parse_args(argv)
    recs = load(args.artifact)
    print("## §Dry-run\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16x16)\n")
    print(roofline_table(recs))
    print("\n## hillclimb candidates\n")
    print(json.dumps({k: list(v) for k, v in pick_hillclimb_cells(recs).items()},
                     indent=1))


if __name__ == "__main__":
    main()
