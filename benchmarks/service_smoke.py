"""Tuning-service crash-recovery smoke: the CI gate for tentpole PR 7.

Spins up the real processes — one ``launch/service.py --serve`` daemon
driving two ``launch/worker.py`` measurement daemons over localhost
TCP — submits two concurrent jobs through the protocol-v2 client,
SIGKILLs the daemon mid-run, restarts it on the same state dir, and
gates the service's crash contract:

* **0 lost completed results** — every evaluation in a job's history
  the instant before the kill is still there, in order, at the end;
* **0 double-recorded results** — no point appears twice in a finished
  job's history (``History.save`` persists completed evals atomically,
  so a SIGKILL can lose at most in-flight work, never duplicate it);
* **both jobs finish** — the restarted daemon recovers every
  non-terminal job document and resumes it from its checkpoint to the
  full budget;
* the resumed runs *made progress before the kill* (the kill happened
  mid-run, not before or after — otherwise the gate proves nothing).

Usage (CI runs exactly this):

    PYTHONPATH=src:. python -m benchmarks.service_smoke --check \
        --out BENCH_service.json
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import tempfile
import time

BUDGET = 14  # per job; 2 jobs x 14 evals over a 4-slot fleet
N_JOBS = 2
MIN_EVALS_BEFORE_KILL = 3  # per job: the kill must land mid-run


def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _env(root: pathlib.Path) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root)]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    return env


def spawn_worker(root: pathlib.Path, port: int) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.worker",
         "--host", "127.0.0.1", "--port", str(port),
         "--slots", "2", "--heartbeat", "0.5", "--objective",
         "benchmarks.perf_iterations:make_remote_bench_objective()"],
        env=_env(root), cwd=str(root),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def spawn_daemon(root: pathlib.Path, state_dir: str, port: int,
                 worker_ports: list) -> subprocess.Popen:
    fleet = ",".join(f"127.0.0.1:{p}" for p in worker_ports)
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.service", "--serve",
         "--state-dir", state_dir, "--host", "127.0.0.1",
         "--port", str(port), "--workers", fleet],
        env=_env(root), cwd=str(root),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def connect_client(address: str, timeout_s: float = 20.0):
    from repro.launch.service import ServiceClient

    deadline = time.time() + timeout_s
    while True:
        try:
            return ServiceClient(address)
        except (ConnectionError, OSError):
            if time.time() >= deadline:
                raise
            time.sleep(0.1)


def read_history(state_dir: pathlib.Path, job_id: str) -> list:
    path = state_dir / "jobs" / job_id / "history.json"
    if not path.exists():
        return []
    try:
        return json.loads(path.read_text())
    except ValueError:
        return []  # mid-replace torn read; treated as empty for polling


def run_smoke(emit=print) -> dict:
    from repro.tuning.protocol import JobSpec

    root = pathlib.Path(__file__).resolve().parents[1]
    space = [{"type": "int", "name": "inter_op", "min": 1, "max": 16},
             {"type": "int", "name": "intra_op", "min": 0, "max": 60,
              "step": 5},
             {"type": "cat", "name": "build", "choices": [1, 2, 3]}]
    # exhaustive: deterministic, dedup-on-resume, so "no duplicates"
    # is exact — random engines legitimately re-record memoized repeats
    config = {"algorithm": "exhaustive", "budget": BUDGET, "verbose": False}

    worker_ports = [free_port(), free_port()]
    daemon_port = free_port()
    workers = [spawn_worker(root, p) for p in worker_ports]
    daemon = None
    t0 = time.perf_counter()
    try:
        with tempfile.TemporaryDirectory() as d:
            state = pathlib.Path(d) / "state"
            daemon = spawn_daemon(root, str(state), daemon_port,
                                  worker_ports)
            address = f"127.0.0.1:{daemon_port}"
            with connect_client(address) as client:
                job_ids = [
                    client.submit(JobSpec(space=space, config=config,
                                          name=f"smoke-{i}"))
                    for i in range(N_JOBS)]
                emit(f"[service-smoke] submitted {job_ids} "
                     f"(budget {BUDGET} each)")
                # let both jobs make real progress, then kill mid-run
                deadline = time.time() + 60
                while time.time() < deadline:
                    done = min(len(read_history(state, j))
                               for j in job_ids)
                    if done >= MIN_EVALS_BEFORE_KILL:
                        break
                    time.sleep(0.05)

            daemon.send_signal(signal.SIGKILL)
            daemon.wait(timeout=10)
            before = {j: read_history(state, j) for j in job_ids}
            kill_evals = {j: len(h) for j, h in before.items()}
            emit(f"[service-smoke] SIGKILL'd daemon at {kill_evals} evals")

            # restart on the same state dir: recovery must resume both
            daemon = spawn_daemon(root, str(state), daemon_port,
                                  worker_ports)
            with connect_client(address) as client:
                finals = {j: client.wait(j, timeout=120) for j in job_ids}

            after = {j: read_history(state, j) for j in job_ids}
            wall_s = time.perf_counter() - t0

            per_job = []
            for j in job_ids:
                keys = [tuple(sorted(e["point"].items())) for e in after[j]]
                per_job.append({
                    "job_id": j,
                    "state": finals[j]["state"],
                    "evals_at_kill": kill_evals[j],
                    "evals_final": len(after[j]),
                    "lost_completed": sum(
                        1 for i, e in enumerate(before[j])
                        if i >= len(after[j]) or after[j][i] != e),
                    "double_recorded": len(keys) - len(set(keys)),
                    "best": finals[j].get("best", {}).get("value"),
                })

            gates = {
                "both_jobs_done": all(r["state"] == "done"
                                      for r in per_job),
                "full_budget": all(r["evals_final"] == BUDGET
                                   for r in per_job),
                "zero_lost_completed": all(r["lost_completed"] == 0
                                           for r in per_job),
                "zero_double_recorded": all(r["double_recorded"] == 0
                                            for r in per_job),
                "kill_was_mid_run": all(
                    0 < r["evals_at_kill"] < BUDGET for r in per_job),
            }
            return {"bench": "service_smoke", "budget": BUDGET,
                    "n_jobs": N_JOBS, "wall_s": round(wall_s, 3),
                    "jobs": per_job, "gates": gates,
                    "ok": all(gates.values())}
    finally:
        for proc in [daemon] + workers:
            if proc is not None and proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=5)
                except subprocess.TimeoutExpired:
                    proc.kill()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any gate fails")
    args = ap.parse_args(argv)

    result = run_smoke()
    print(json.dumps(result, indent=2))
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(result, indent=2))
        print(f"[service-smoke] wrote {args.out}")
    if args.check and not result["ok"]:
        failed = [g for g, ok in result["gates"].items() if not ok]
        print(f"[service-smoke] FAILED gates: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
