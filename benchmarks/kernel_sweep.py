"""Flagship kernel-autotuning sweep: tune the repo's own Pallas kernels
and persist the answers in a :class:`~repro.tuning.tundb.TuningDB`.

    PYTHONPATH=src:. python -m benchmarks.kernel_sweep \
        --db artifacts/tundb.json --kernels rmsnorm gla_scan --budget 6

This is the artifact-producing loop the ROADMAP's "TopHub" item asks
for: per kernel, a gradient-free search over its Pallas tile knobs
(``repro.tuning.kernel_objective``), measured with the shared
variance-adaptive wall-clock harness, best config + provenance written
to the DB keyed by (kernel, shape bucket, hardware fingerprint).  Every
later serve/train run started with ``--tuning-db <path>`` then picks the
tuned tiles up at trace time.

The sweep is *warm-start aware*: a kernel whose (shape bucket,
fingerprint) already has a DB record is skipped outright — a second
identical sweep re-measures **nothing** (the acceptance gate of
``--check``, enforced in CI's ``kernel-sweep-smoke``), mirroring the
pay-once amortization argument of the source papers.  The tuner's
async completion-driven loop, ASHA multi-fidelity rungs
(``--multi-fidelity``) and the remote worker backend (``--workers``)
compose unchanged under this driver.

``--check`` gates (CI):
  * cold sweep over >= 2 kernels measures > 0 configs and persists a DB;
  * a warm re-run of the identical sweep performs 0 re-measurements;
  * trace-time DB lookup costs < 1 ms median (it runs during jit
    tracing, so it must be negligible there).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

from repro.core import Tuner, TunerConfig
from repro.core.space import SearchSpace
from repro.tuning.kernel_objective import KERNELS, KernelTuneEvaluator, kernel_space
from repro.tuning.objective import CountingEvaluator
from repro.tuning.tundb import TuningDB


def run_sweep(kernels, db: TuningDB, *, budget: int = 6,
              algorithm: str = "random", parallelism: int = 1,
              multi_fidelity: bool = False, workers=None, shapes=None,
              warmup: int = 1, iters: int = 2, rel_halfwidth: float = 0.5,
              seed: int = 0, emit=print):
    """Tune each kernel (unless the DB already holds its answer).

    Returns ``(rows, measured)`` — per-kernel result rows and the total
    number of *real* measurements performed (0 on a warm DB).
    """
    rows, measured = [], 0
    for name in kernels:
        spec = KERNELS[name]
        shape = dict((shapes or {}).get(name, spec.shape))
        hit = db.lookup(name, shape)
        if hit is not None:
            rows.append({"kernel": name, "shape": shape, "skipped": True,
                         "measurements": 0, "best": hit["config"],
                         "value": hit["value"]})
            emit(f"kernelsweep,{name},warm,0,{hit['value']:.4g},"
                 f"{json.dumps(hit['config'], sort_keys=True)}")
            continue
        evaluator = CountingEvaluator(KernelTuneEvaluator(
            name, shape, warmup=warmup, iters=iters,
            rel_halfwidth=rel_halfwidth))
        space = SearchSpace.from_dicts(kernel_space(name, shape))
        t = Tuner(evaluator, space,
                  TunerConfig(algorithm=algorithm,
                              budget=min(budget, space.grid_size()),
                              seed=seed, verbose=False,
                              parallelism=parallelism,
                              multi_fidelity=multi_fidelity,
                              workers=list(workers) if workers else None))
        t0 = time.perf_counter()
        h = t.run()
        secs = time.perf_counter() - t0
        t.close()
        best = h.best(full_fidelity_only=multi_fidelity)
        db.record(name, shape, best.point, best.value,
                  fidelity=best.fidelity,
                  job_id=f"kernel_sweep:{algorithm}:seed{seed}")
        measured += evaluator.calls
        rows.append({"kernel": name, "shape": shape, "skipped": False,
                     "measurements": evaluator.calls, "n_evals": len(h),
                     "best": best.point, "value": best.value,
                     "seconds": round(secs, 3)})
        emit(f"kernelsweep,{name},cold,{evaluator.calls},{best.value:.4g},"
             f"{json.dumps(best.point, sort_keys=True)}")
    return rows, measured


def lookup_latency_ms(db: TuningDB, kernels, shapes=None,
                      trials: int = 200) -> float:
    """Median trace-time lookup cost in milliseconds.

    The dispatch layer calls ``db.kernel_config`` once per kernel per
    trace; anything near a millisecond would be invisible next to jit
    tracing, but the gate pins it anyway so a regression (say, a file
    read per lookup) cannot hide."""
    times = []
    for _ in range(trials):
        for name in kernels:
            shape = dict((shapes or {}).get(name, KERNELS[name].shape))
            t0 = time.perf_counter()
            db.kernel_config(name, shape)
            times.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(times)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--db", required=True, metavar="PATH",
                    help="TuningDB json path (created if absent)")
    ap.add_argument("--kernels", nargs="+", default=sorted(KERNELS),
                    choices=sorted(KERNELS))
    ap.add_argument("--budget", type=int, default=6,
                    help="tuning evaluations per kernel")
    ap.add_argument("--algorithm", default="random",
                    help="ask/tell engine: bo|ga|nms|random|exhaustive")
    ap.add_argument("--parallelism", type=int, default=1)
    ap.add_argument("--multi-fidelity", action="store_true",
                    help="screen candidates on ASHA rungs (partial "
                         "wall-clock measurements)")
    ap.add_argument("--workers", nargs="*", default=None,
                    help="host:port measurement worker daemons "
                         "(launch/worker.py)")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write result rows as json")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: after the sweep, a warm re-run must "
                         "re-measure 0 configs and median DB lookup must "
                         "stay under 1 ms")
    args = ap.parse_args(argv)

    db = TuningDB(args.db)
    rows, measured = run_sweep(
        args.kernels, db, budget=args.budget, algorithm=args.algorithm,
        parallelism=args.parallelism, multi_fidelity=args.multi_fidelity,
        workers=args.workers, iters=args.iters, seed=args.seed)
    print(f"[kernel_sweep] {len(args.kernels)} kernels, {measured} "
          f"measurements, db={args.db} ({len(db)} records)")

    failures = []
    if args.check:
        if measured == 0:
            failures.append("cold sweep performed no measurements "
                            "(delete the db for a true cold run)")
        # warm re-run against a FRESH TuningDB instance on the same path:
        # everything must come back from disk, nothing re-measured
        warm_db = TuningDB(args.db)
        warm_rows, warm_measured = run_sweep(
            args.kernels, warm_db, budget=args.budget,
            algorithm=args.algorithm, parallelism=args.parallelism,
            multi_fidelity=args.multi_fidelity, workers=args.workers,
            iters=args.iters, seed=args.seed)
        rows += [dict(r, phase="warm") for r in warm_rows]
        if warm_measured != 0:
            failures.append(f"warm re-run re-measured {warm_measured} "
                            "configs (must be 0)")
        ms = lookup_latency_ms(warm_db, args.kernels)
        rows.append({"mode": "lookup_latency", "median_ms": round(ms, 5)})
        print(f"[kernel_sweep] warm re-measurements={warm_measured}, "
              f"lookup median={ms:.4f}ms")
        if ms >= 1.0:
            failures.append(f"median DB lookup {ms:.3f}ms >= 1ms")

    if args.out:
        p = pathlib.Path(args.out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rows, indent=1))
    if args.check and failures:
        raise SystemExit("kernel-sweep regression: " + "; ".join(failures))


if __name__ == "__main__":
    main()
