"""Tuning workloads mirroring the paper's model variety (§4.1).

| paper model        | domain            | here                          |
|--------------------|-------------------|-------------------------------|
| SSD-MobileNet      | vision            | `convnet` (dw-separable CNN)  |
| ResNet50 (FP32/I8) | vision            | `convnet` precision dim       |
| Transformer-LT     | translation       | `dense_lm` (tiny qwen2)       |
| BERT               | language          | `moe_lm` (tiny qwen3-MoE)     |
| NCF                | recommendation    | `ncf` (embedding + MLP)       |
| —                  | (new) ssm         | `rwkv` (tiny RWKV-6)          |

Each workload exposes
  * ``space``      — its tunable backend parameters (paper Table 1 shape)
  * measured path  — ``make_step(point)`` for WallClockEvaluator (real
    compile+run on the local device; the paper's measurement harness)
  * surrogate path — ``surrogate_objective`` — a deterministic analytic
    throughput model (compute/memory two-term roofline + interaction and
    plateau structure + 2% hash noise) used for fast CI and the
    many-seed comparative statistics.
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import numpy as np

# --- the five workload definitions -----------------------------------------

_COMMON_DIMS = [
    {"name": "batch", "type": "cat", "choices": [2, 4, 8, 16]},
    {"name": "microbatches", "type": "cat", "choices": [1, 2, 4]},
    {"name": "remat", "type": "cat", "choices": ["none", "dots", "names", "full"]},
]

MEASURED_WORKLOADS = [
    {
        "name": "dense_lm",
        "arch": "qwen2-0.5b",
        "kind": "lm",
        "space": _COMMON_DIMS + [
            {"name": "block_q", "type": "int", "min": 8, "max": 64, "step": 8},
        ],
        # surrogate shape: flops/byte weights + sweet spots
        "surr": {"flop": 1.0, "mem": 0.7, "bq_opt": 32, "mb_cost": 0.06,
                 "remat_gain": 0.25, "mode2": 0.35},
    },
    {
        "name": "moe_lm",
        "arch": "qwen3-moe-30b-a3b",
        "kind": "lm",
        "space": _COMMON_DIMS + [
            {"name": "block_q", "type": "int", "min": 8, "max": 64, "step": 8},
            {"name": "capacity_factor", "type": "cat",
             "choices": [1.0, 1.25, 1.5, 2.0]},
        ],
        "surr": {"flop": 1.1, "mem": 1.0, "bq_opt": 16, "mb_cost": 0.05,
                 "remat_gain": 0.1, "mode2": 0.55, "cf_opt": 1.25},
    },
    {
        "name": "rwkv",
        "arch": "rwkv6-3b",
        "kind": "lm",
        "space": _COMMON_DIMS + [
            {"name": "scan_chunk", "type": "int", "min": 8, "max": 64, "step": 8},
        ],
        "surr": {"flop": 0.9, "mem": 1.2, "bq_opt": 24, "mb_cost": 0.08,
                 "remat_gain": 0.35, "mode2": 0.2, "chunk_dim": "scan_chunk"},
    },
    {
        "name": "convnet",
        "arch": None,
        "kind": "conv",
        "space": _COMMON_DIMS + [
            {"name": "channels_last", "type": "cat", "choices": [0, 1]},
        ],
        "surr": {"flop": 1.3, "mem": 0.8, "bq_opt": 40, "mb_cost": 0.1,
                 "remat_gain": 0.15, "mode2": 0.45},
    },
    {
        "name": "ncf",
        "arch": None,
        "kind": "ncf",
        "space": [
            {"name": "batch", "type": "cat", "choices": [64, 128, 256, 512]},
            {"name": "microbatches", "type": "cat", "choices": [1, 2, 4]},
            {"name": "remat", "type": "cat",
             "choices": ["none", "dots", "names", "full"]},
            {"name": "embed_block", "type": "int", "min": 8, "max": 64, "step": 8},
        ],
        "surr": {"flop": 0.6, "mem": 1.5, "bq_opt": 48, "mb_cost": 0.12,
                 "remat_gain": 0.05, "mode2": 0.25, "bq_dim": "embed_block"},
    },
]


def _hash01(*vals) -> float:
    h = 0x9E3779B97F4A7C15
    for v in vals:
        h ^= abs(hash(v))
        h = (h * 0xBF58476D1CE4E5B9) % (2 ** 64)
        h ^= h >> 31
    return (h % 10_000) / 10_000.0


def surrogate_objective(workload: Dict) -> Callable[[Dict], float]:
    """Analytic two-term throughput model with the qualitative structure
    observed in the paper's Fig. 6 sweep: one dominant parameter, one
    near-flat parameter, a tile-size sweet spot, and a secondary mode."""
    s = workload["surr"]
    bq_dim = s.get("bq_dim", s.get("chunk_dim", "block_q"))

    def f(p: Dict) -> float:
        batch = p["batch"]
        mb = p["microbatches"]
        remat = p["remat"]
        bq = p.get(bq_dim, s["bq_opt"])

        # compute term: larger effective batch = better MXU utilization
        eff = batch / mb
        compute = s["flop"] / (1.0 - math.exp(-eff / 6.0))
        # tile sweet spot (primary mode) + secondary mode at half the tile
        tile = 1.0 + 0.8 * (math.log2(bq / s["bq_opt"])) ** 2 * 0.15
        tile2 = 1.0 + 0.8 * (math.log2(max(bq, 1) / max(s["bq_opt"] // 4, 1))) ** 2 * 0.15
        tile = min(tile, tile2 * (1 + s["mode2"]))
        # memory term: remat trades capacity for recompute
        remat_cost = {"none": 1.0, "dots": 1.05, "names": 1.12, "full": 1.3}[remat]
        fits = eff * (1.0 if remat != "none" else 1.6) <= 18
        mem = s["mem"] * (1.0 if fits else 4.0)  # spill cliff
        # microbatch fixed overhead
        overhead = 1.0 + s["mb_cost"] * (mb - 1)
        if "capacity_factor" in p:
            cf = p["capacity_factor"]
            overhead *= 1.0 + 0.3 * abs(cf - s.get("cf_opt", 1.25))
        step = max(compute * tile * remat_cost, mem) * overhead
        tput = 1000.0 * batch / step
        noise = 1.0 + 0.02 * (_hash01(workload["name"], tuple(sorted(p.items()))) - 0.5)
        return tput * noise

    return f


# --- measured (wall-clock) builders -----------------------------------------


def _lm_make_step(workload: Dict):

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.params import split_params
    from repro.models.runtime import Runtime
    from repro.optim.optimizer import OptimizerConfig, adamw_init
    from repro.train.train_step import make_train_step

    cfg = get_config(workload["arch"]).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    opt_cfg = OptimizerConfig(warmup_steps=1)
    opt = adamw_init(params, opt_cfg)
    S = 64
    rng = np.random.default_rng(0)

    def make_step(point: Dict):
        B = point["batch"]
        rt = Runtime(
            compute_dtype="f32",
            remat=point["remat"],
            attn_impl="chunked",
            block_q=point.get("block_q", 32),
            block_kv=point.get("block_q", 32),
            scan_chunk=point.get("scan_chunk", 16),
            moe_capacity_factor=point.get("capacity_factor", 0.0),
        )
        step = make_train_step(model, opt_cfg, rt,
                               microbatches=point["microbatches"])
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
        }

        def fn(params, opt, batch):
            _, _, m = step(params, opt, batch)
            return m["loss"]

        return fn, (params, opt, batch), float(B * S)

    return make_step


def _conv_make_step(workload: Dict):
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    C, H = 16, 32
    ws = {
        "dw1": jnp.asarray(0.1 * rng.standard_normal((3, 3, C, 1)), jnp.float32),
        "pw1": jnp.asarray(0.1 * rng.standard_normal((1, 1, C, 2 * C)), jnp.float32),
        "dw2": jnp.asarray(0.1 * rng.standard_normal((3, 3, 2 * C, 1)), jnp.float32),
        "pw2": jnp.asarray(0.1 * rng.standard_normal((1, 1, 2 * C, 2 * C)), jnp.float32),
        "head": jnp.asarray(0.1 * rng.standard_normal((2 * C, 10)), jnp.float32),
    }

    def net(ws, x):
        for dw, pw in (("dw1", "pw1"), ("dw2", "pw2")):
            x = jax.lax.conv_general_dilated(
                x, ws[dw], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=x.shape[-1])
            x = jax.nn.relu(jax.lax.conv_general_dilated(
                x, ws[pw], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")))
        x = x.mean(axis=(1, 2))
        return x @ ws["head"]

    def make_step(point: Dict):
        B = point["batch"]
        x = jnp.asarray(rng.standard_normal((B, H, H, C)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 10, (B,)), jnp.int32)

        def loss_fn(ws):
            def inner(ws, x, y):
                logits = net(ws, x)
                return -jnp.take_along_axis(
                    jax.nn.log_softmax(logits), y[:, None], 1).mean()
            f = inner
            if point["remat"] != "none":
                f = jax.checkpoint(inner)
            if point["microbatches"] > 1:
                k = point["microbatches"]
                if B % k == 0:
                    losses = [f(ws, x[i::k], y[i::k]) for i in range(k)]
                    return sum(losses) / k
            return f(ws, x, y)

        def fn(ws):
            return jax.grad(lambda w: loss_fn(w))(ws)["head"].sum()

        return fn, (ws,), float(B)

    return make_step


def _ncf_make_step(workload: Dict):
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    n_users, n_items, dim = 2000, 3000, 32
    ws = {
        "ue": jnp.asarray(0.1 * rng.standard_normal((n_users, dim)), jnp.float32),
        "ie": jnp.asarray(0.1 * rng.standard_normal((n_items, dim)), jnp.float32),
        "w1": jnp.asarray(0.1 * rng.standard_normal((2 * dim, 64)), jnp.float32),
        "w2": jnp.asarray(0.1 * rng.standard_normal((64, 1)), jnp.float32),
    }

    def make_step(point: Dict):
        B = point["batch"]
        u = jnp.asarray(rng.integers(0, n_users, (B,)), jnp.int32)
        i = jnp.asarray(rng.integers(0, n_items, (B,)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 2, (B,)), jnp.float32)

        def loss_fn(ws):
            ue = jnp.take(ws["ue"], u, axis=0)
            ie = jnp.take(ws["ie"], i, axis=0)
            h = jax.nn.relu(jnp.concatenate([ue, ie], -1) @ ws["w1"])
            logit = (h @ ws["w2"])[:, 0] + (ue * ie).sum(-1)
            return jnp.mean(jnp.logaddexp(0.0, logit) - y * logit)

        def fn(ws):
            return jax.grad(loss_fn)(ws)["w1"].sum()

        return fn, (ws,), float(B)

    return make_step


def measured_make_step(workload: Dict):
    if workload["kind"] == "lm":
        return _lm_make_step(workload)
    if workload["kind"] == "conv":
        return _conv_make_step(workload)
    if workload["kind"] == "ncf":
        return _ncf_make_step(workload)
    raise ValueError(workload["kind"])
