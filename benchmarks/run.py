"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--measured]

Emits CSV lines ``name,...`` per artifact:
  fig5_*    — tuning-curve comparison (paper Fig. 5)
  fig6_*    — exhaustive sweep + sensitivity (paper Fig. 6)
  table2_*  — sampled-range coverage (paper Table 2 / Fig. 7)
  roofline  — the 40-cell (x2 mesh) dry-run roofline table (§Roofline)
"""
import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller budgets/seeds for CI")
    ap.add_argument("--measured", action="store_true",
                    help="fig5 measures real wall-clock configurations")
    ap.add_argument("--parallelism", type=int, default=1,
                    help="evaluation worker-pool width for the tuning "
                         "sections (batched ask/tell executor)")
    args = ap.parse_args(argv)

    from benchmarks import fig5_tuning_curves, fig6_exhaustive, roofline, table2_exploration

    budget = 25 if args.fast else 50
    seeds = 2 if args.fast else 3

    t0 = time.perf_counter()
    fig5_tuning_curves.run(measured=args.measured, budget=budget, seeds=seeds,
                           parallelism=args.parallelism)
    print(f"# fig5 done in {time.perf_counter()-t0:.1f}s")

    t0 = time.perf_counter()
    fig6_exhaustive.run("dense_lm")
    print(f"# fig6 done in {time.perf_counter()-t0:.1f}s")

    t0 = time.perf_counter()
    table2_exploration.run(budget=budget)
    print(f"# table2 done in {time.perf_counter()-t0:.1f}s")

    t0 = time.perf_counter()
    roofline.run()
    print(f"# roofline done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
