"""Scheduler-zoo smoke: the CI gate for tentpole PR 10.

Two gates over the ``TrialScheduler`` seam:

* **hedging** — on a skewed objective (low-fidelity screening is
  deterministically biased against part of the space, measurement cost
  proportional to fidelity), HyperBand's staggered brackets must
  *confirm* a value within 1% of the true optimum at **full fidelity**
  in <= ``HB_WALL_RATIO`` x ASHA's wall clock — or confirm it at all
  when ASHA never does (the skew tricks the single aggressive ladder
  into culling the optimum at its bottom rung; brackets hedge);
* **fork-kill** — a PBT run over a real ``launch/worker.py`` fleet
  survives a mid-run SIGKILL of one measurement host: the killed
  worker's in-flight steps (checkpoint-fork ``state`` blobs riding the
  v2 task payload) are reinjected onto the survivor, the run completes
  its budget, and the history holds **0 duplicate and 0 lost**
  (lineage, step) records — exactly-once accounting through fork,
  re-dispatch, and death — with at least one exploit/explore fork
  actually exercised.

Workers serve ``make_fork_objective()`` from this module: value is a
deterministic function of the point plus a small warm-start bonus per
resumed step, so lineages measurably benefit from their checkpoints.

Usage (CI runs exactly this):

    PYTHONPATH=src:. python -m benchmarks.scheduler_smoke --check \
        --out BENCH_schedulers.json
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import signal
import threading
import time

from benchmarks.elastic_smoke import _env, free_port, reap, wait_port

HB_WALL_RATIO = 1.2    # hyperband wall-to-within-1% / asha's must be <= this
HEDGE_SLEEP_S = 0.04   # full-fidelity measurement cost (scales with f)
HEDGE_BUDGET = 60      # full-measurement equivalents per scheduler run
PBT_BUDGET = 30
PBT_STEP_SLEEP_S = 0.05
KILL_AFTER_EVALS = 8


# ---------------------------------------------------------------------------
# gate (a): HyperBand hedges the skew without losing ASHA's wall clock
# ---------------------------------------------------------------------------

def _true_value(p) -> float:
    return float(p["a"] * 10 + p["b"] + (5 if p["c"] == "y" else 0))


def make_skewed_objective():
    """Fidelity-capable objective whose cheap screening lies about part
    of the space: points with odd ``a`` look up to ~60% worse than they
    are at low fidelity (the bias decays linearly with fidelity).  An
    aggressive single ladder culls the true optimum at its bottom rung;
    staggered brackets hedge.  Cost is fidelity-proportional."""
    from repro.tuning.objective import Evaluator

    class SkewedObjective(Evaluator):
        supports_fidelity = True

        def __init__(self):
            self.log = []  # (t, true_value) per real measurement

        def __call__(self, point, fidelity=None):
            f = 1.0 if fidelity is None else float(fidelity)
            time.sleep(HEDGE_SLEEP_S * f)
            v = _true_value(point)
            if point["a"] % 2 == 1:
                v *= 1.0 - 0.6 * (1.0 - f)  # skew: odd-a looks bad cheap
            self.log.append((time.perf_counter(), _true_value(point), f))
            return v, {"cost_seconds": HEDGE_SLEEP_S * f}

    return SkewedObjective()


def _wall_to_within(log, optimum: float, frac: float = 0.01):
    """Seconds from the first measurement until a FULL-fidelity
    measurement confirms a true value within ``frac`` of the optimum;
    None if never.  Cheap screens don't count: a scheduler only "finds"
    the optimum once it has promoted it all the way up, which is exactly
    what the skew tries to prevent."""
    if not log:
        return None
    t0, best = log[0][0], -math.inf
    for t, v, f in log:
        if f < 1.0:
            continue
        best = max(best, v)
        if best >= optimum * (1.0 - frac):
            return t - t0
    return None


def bench_hedging(emit) -> dict:
    from repro.core import (IntDim, CatDim, MultiFidelityConfig, SearchSpace,
                            Tuner, TunerConfig)

    # small enough that both schedulers can cover it within the budget
    # (the gate measures wall clock to the optimum, not whether it is
    # ever found); the optimum sits at odd a, squarely under the skew
    space = SearchSpace([IntDim("a", 0, 5), IntDim("b", 0, 5),
                         CatDim("c", ["x", "y"])])
    optimum = _true_value({"a": 5, "b": 5, "c": "y"})
    walls = {}
    for kind in ("asha", "hyperband"):
        obj = make_skewed_objective()
        # parallelism=1 keeps the random-engine stream deterministic per
        # seed, so the gate never flakes on thread completion order
        t = Tuner(obj, space, TunerConfig(
            algorithm="random", budget=HEDGE_BUDGET, seed=7, verbose=False,
            parallelism=1,
            multi_fidelity=MultiFidelityConfig(
                enabled=True, scheduler=kind, min_fidelity=1 / 9, eta=3)))
        t.run()
        t.close()
        walls[kind] = _wall_to_within(obj.log, optimum)
    both = all(w is not None for w in walls.values())
    ratio = (walls["hyperband"] / walls["asha"]) if both else None
    # the gate: hyperband must confirm the optimum, and do so within
    # HB_WALL_RATIO x asha's wall — where asha never confirming at all
    # (the skew culled the optimum below the top rung) counts as a win
    ok = walls["hyperband"] is not None and (
        walls["asha"] is None
        or walls["hyperband"] <= HB_WALL_RATIO * walls["asha"])
    emit(f"[scheduler-smoke] hedging: asha {walls['asha']} s vs hyperband "
         f"{walls['hyperband']} s to full-fidelity within-1% confirmation "
         f"(ratio {ratio if ratio is None else round(ratio, 2)})")
    return {"asha_wall_s": walls["asha"], "hyperband_wall_s": walls["hyperband"],
            "ratio": None if ratio is None else round(ratio, 3),
            "gate": HB_WALL_RATIO, "ok": ok}


# ---------------------------------------------------------------------------
# gate (b): PBT checkpoint-fork survives a mid-run worker SIGKILL
# ---------------------------------------------------------------------------

def make_fork_objective():
    """Deterministic fork-capable objective served by worker daemons:
    each resumed step adds a small warm-start bonus, so checkpoints are
    worth carrying and a dropped ``state`` blob is observable."""
    from repro.tuning.objective import Evaluator

    class ForkObjective(Evaluator):
        supports_fidelity = True
        supports_fork = True

        def __call__(self, point, fidelity=None, resume_state=None):
            time.sleep(PBT_STEP_SLEEP_S)
            warm = int((resume_state or {}).get("warm", 0))
            v = float(point["a"] * 10 + point["b"]) + 0.01 * warm
            return v, {"fork_state": {"warm": warm + 1},
                       "cost_seconds": PBT_STEP_SLEEP_S}

    return ForkObjective()


def bench_fork_kill(root, emit) -> dict:
    from repro.core import (IntDim, MultiFidelityConfig, SearchSpace, Tuner,
                            TunerConfig)

    p1, p2 = free_port(), free_port()
    w1 = _spawn_fork_worker(root, p1)
    w2 = _spawn_fork_worker(root, p2)
    try:
        wait_port(p1)
        wait_port(p2)
        space = SearchSpace([IntDim("a", 0, 9), IntDim("b", 0, 9)])
        mf = MultiFidelityConfig(enabled=True, scheduler="pbt",
                                 min_fidelity=0.5)
        mf.pbt.population = 4
        tuner = Tuner(make_fork_objective(), space, TunerConfig(
            algorithm="random", budget=PBT_BUDGET, seed=11, verbose=False,
            workers=[f"127.0.0.1:{p1}", f"127.0.0.1:{p2}"],
            multi_fidelity=mf))
        done = threading.Event()

        def _run():
            try:
                tuner.run()
            finally:
                done.set()

        t = threading.Thread(target=_run, daemon=True)
        t.start()
        # kill one measurement host once the run is warm (steps in
        # flight, forks plausible): its tasks — state blobs included —
        # must be reinjected onto the survivor
        deadline = time.time() + 60
        while len(tuner.history) < KILL_AFTER_EVALS \
                and time.time() < deadline:
            time.sleep(0.02)
        killed_at = len(tuner.history)
        w1.send_signal(signal.SIGKILL)
        w1.wait(timeout=10)
        finished = done.wait(timeout=120)
        stats = tuner.rung_scheduler.stats()[0]
        pairs = [(e.lineage, e.rung) for e in tuner.history.evals]
        dupes = len(pairs) - len(set(pairs))
        lost = 0 if finished else 1  # a hung run == lost work
        warm = sum(1 for e in tuner.history.evals
                   if (e.meta.get("fork_state") or {}).get("warm", 0) > 1)
        tuner.close()
    finally:
        reap(w1, w2)
    emit(f"[scheduler-smoke] fork-kill: {len(pairs)} steps recorded "
         f"(killed host at {killed_at}), {dupes} duplicates, "
         f"forks={stats['forks']}, warm-resumed={warm}")
    return {"steps": len(pairs), "killed_at_evals": killed_at,
            "duplicates": dupes, "lost": lost, "forks": stats["forks"],
            "warm_resumed": warm, "finished": finished,
            "ok": (finished and dupes == 0 and lost == 0
                   and stats["forks"] >= 1 and warm >= 1)}


def _spawn_fork_worker(root, port):
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "repro.launch.worker",
           "--host", "127.0.0.1", "--port", str(port), "--slots", "2",
           "--heartbeat-s", "0.2", "--objective",
           "benchmarks.scheduler_smoke:make_fork_objective()"]
    return subprocess.Popen(cmd, env=_env(root), cwd=str(root),
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)


def run_smoke(emit=print) -> dict:
    root = pathlib.Path(__file__).resolve().parents[1]
    t0 = time.perf_counter()
    hedging = bench_hedging(emit)
    fork_kill = bench_fork_kill(root, emit)
    gates = {
        "hyperband_hedges_within_wall_gate": hedging["ok"],
        "pbt_fork_survives_sigkill": fork_kill["ok"],
    }
    return {"bench": "scheduler_smoke",
            "hb_wall_ratio_gate": HB_WALL_RATIO,
            "wall_s": round(time.perf_counter() - t0, 3),
            "hedging": hedging, "fork_kill": fork_kill,
            "gates": gates, "ok": all(gates.values())}


def main(argv=None):
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any gate fails")
    args = ap.parse_args(argv)

    result = run_smoke()
    print(json.dumps(result, indent=2))
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(result, indent=2))
        print(f"[scheduler-smoke] wrote {args.out}")
    if args.check and not result["ok"]:
        failed = [g for g, ok in result["gates"].items() if not ok]
        print(f"[scheduler-smoke] FAILED gates: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
