"""Paper Fig. 6: exhaustive sweep + per-parameter sensitivity.

Sweeps the full grid of one workload's space, then reports
  * the global optimum,
  * per-parameter sensitivity (mean throughput spread when the parameter
    varies with all others fixed — the paper's "which knob matters"),
  * the exhaustive-search cost argument from §1: grid points x per-eval
    cost vs the 50-evaluation tuner budget.

CSV rows: fig6_best / fig6_sensitivity / fig6_cost / fig6_tuner_gap.
"""
from __future__ import annotations

import argparse
import itertools
import time

import numpy as np

from benchmarks.workloads import MEASURED_WORKLOADS, surrogate_objective
from repro.core import SearchSpace, Tuner, TunerConfig


def sensitivity(space: SearchSpace, values: dict) -> dict:
    """Mean range of the objective along each axis, others held fixed."""
    out = {}
    for d in space.dims:
        spreads = []
        others = [dd for dd in space.dims if dd.name != d.name]
        combos = itertools.product(*[dd.values for dd in others])
        for combo in itertools.islice(combos, 500):
            base = dict(zip([dd.name for dd in others], combo))
            ys = [values[space.key({**base, d.name: v})] for v in d.values]
            spreads.append(max(ys) - min(ys))
        out[d.name] = float(np.mean(spreads))
    return out


def run(workload_name: str = "dense_lm", emit=print):
    w = next(w for w in MEASURED_WORKLOADS if w["name"] == workload_name)
    space = SearchSpace.from_dicts(w["space"])
    obj = surrogate_objective(w)

    t0 = time.perf_counter()
    values = {}
    for p in space.enumerate():
        values[space.key(p)] = obj(p)
    sweep_s = time.perf_counter() - t0
    n = space.grid_size()
    per_eval_us = sweep_s / n * 1e6

    best_key = max(values, key=values.get)
    best_point = dict(zip(space.names, best_key))
    emit(f"fig6_best,{workload_name},{values[best_key]:.4f},\"{best_point}\"")

    sens = sensitivity(space, values)
    order = sorted(sens, key=sens.get, reverse=True)
    for name in order:
        emit(f"fig6_sensitivity,{workload_name},{name},{sens[name]:.4f}")

    # the paper's §1 cost argument: exhaustive vs 50-iteration tuning.
    # (their ResNet50 sweep: ~50k points ~= a month of CPU time)
    real_eval_s = 30.0  # a realistic single measured evaluation
    emit(f"fig6_cost,{workload_name},grid={n},exhaustive_hours="
         f"{n * real_eval_s / 3600:.1f},tuner_hours={50 * real_eval_s / 3600:.2f}")

    t = Tuner(obj, space, TunerConfig(algorithm="bo", budget=50, seed=0,
                                      verbose=False))
    h = t.run()
    gap = h.best().value / values[best_key]
    emit(f"fig6_tuner_gap,{workload_name},bo_50_iters_reaches,{gap:.4f}")
    return {"best": best_point, "sensitivity": sens, "bo_gap": gap,
            "per_eval_us": per_eval_us}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="dense_lm")
    args = ap.parse_args(argv)
    run(args.workload)


if __name__ == "__main__":
    main()
