"""Transfer-learning smoke benchmark: warm-starting from the corpus.

    PYTHONPATH=src:. python -m benchmarks.transfer_smoke --check \
        --out BENCH_transfer.json

Three synthetic workloads over the golden search space, each an
:class:`~repro.tuning.objective.Evaluator` that declares roofline-style
``task_features()`` and sleeps a deterministic per-measurement cost:

* **job A** tunes cold and records every completed evaluation into a
  fresh observation corpus (``repro.tuning.corpus``);
* **job B** is a *perturbed neighbor* of A — optimum shifted one grid
  step, values rescaled ~5%, task features ~10% apart — and is tuned
  twice: cold (no corpus) and warm (corpus-configured, so the BO
  surrogate seeds from A's observations under distance-inflated noise
  and the ask batches are pre-filtered against the neighbor prior);
* **job C** is *deliberately dissimilar* (task features ~100x apart, so
  ``workload_distance`` lands far beyond the ``max_distance`` cutoff and
  the corpus must contribute nothing).

``--check`` gates (the CI ``bench-smoke`` step):

* warm job B reaches within 1% of its enumerated grid optimum at least
  **2x faster** than cold job B, in *both* wall-clock seconds and real
  measurement count (aggregated over seeds, time-to-target per run);
* dissimilar job C with the corpus configured regresses by at most
  1.05x against its corpus-free twin (the negative-transfer /
  max-distance guard: better no prior than a misleading one) — the
  traces are in fact byte-identical, which is also asserted;
* with no corpus configured, the BO golden sequential traces
  (``tests/golden/ask_tell_traces.json``, parallelism=1) are reproduced
  **bit-for-bit** — transfer machinery must be strictly additive.
"""
from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import tempfile
import time

from repro.core import SearchSpace, TransferConfig, Tuner, TunerConfig
from repro.tuning.objective import Evaluator

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parents[1]
               / "tests" / "golden" / "ask_tell_traces.json")

#: deterministic simulated measurement cost (seconds of real sleep) —
#: large against the tuner's per-ask overhead so the wall-clock gate
#: measures tuning efficiency, not GP arithmetic
EVAL_SLEEP_S = 0.05


def golden_space() -> SearchSpace:
    golden = json.loads(GOLDEN_PATH.read_text())
    return SearchSpace.from_dicts(golden["space"])


class SyntheticWorkload(Evaluator):
    """One tunable workload: a smooth single-peak landscape over the
    golden space plus roofline-style task features.

    The landscape is deliberately *wide* around its peak (low curvature)
    so "within 1% of the optimum" is a small neighborhood of grid
    points, not a single cell — the same shape real threading-parameter
    sweeps show (arxiv 1812.01665: near-optimal configs cluster).
    """

    def __init__(self, peak, scale: float, features,
                 sleep_s: float = EVAL_SLEEP_S):
        self.peak = dict(peak)
        self.scale = float(scale)
        self.features = dict(features)
        self.sleep_s = float(sleep_s)
        self.log = []  # (perf_counter at completion, value) per real call

    def task_features(self):
        return dict(self.features)

    def true_value(self, p) -> float:
        pk = self.peak
        return self.scale * (
            80.0
            - 0.25 * (p["inter_op"] - pk["inter_op"]) ** 2
            - (p["intra_op"] - pk["intra_op"]) ** 2 / 60.0
            - 8.0 * (p["build"] != pk["build"]))

    def grid_best(self, space: SearchSpace) -> float:
        dims = space.to_dicts()
        axes = []
        for d in dims:
            if d["type"] == "int":
                axes.append(range(d["min"], d["max"] + 1,
                                  d.get("step", 1) or 1))
            else:
                axes.append(d["choices"])
        names = [d["name"] for d in dims]
        return max(self.true_value(dict(zip(names, combo)))
                   for combo in itertools.product(*axes))

    def __call__(self, p, fidelity=None):
        time.sleep(self.sleep_s)
        v = self.true_value(p)
        self.log.append((time.perf_counter(), v))
        return v, {"cost_seconds": self.sleep_s}


# the three workloads; B is A's perturbed neighbor, C is dissimilar
def job_a():
    return SyntheticWorkload(
        peak={"inter_op": 6, "intra_op": 40, "build": 2}, scale=1.0,
        features={"flops": 3.0e12, "bytes": 1.2e10, "intensity": 250.0})


def job_b():
    return SyntheticWorkload(
        peak={"inter_op": 7, "intra_op": 45, "build": 2}, scale=1.05,
        features={"flops": 3.3e12, "bytes": 1.32e10, "intensity": 250.0})


def job_c():
    return SyntheticWorkload(
        peak={"inter_op": 14, "intra_op": 10, "build": 1}, scale=0.9,
        features={"flops": 3.0e10, "bytes": 4.0e8, "intensity": 75.0})


def _tune(workload: SyntheticWorkload, *, seed: int, budget: int,
          corpus_path=None, job_id=None):
    """One parallelism=1 tuning run; returns (history, time-to-target,
    evals-to-target) where the target is within 1% of the enumerated
    grid optimum.  Timing starts before Tuner construction so the warm
    path pays for its corpus read + prior fit."""
    space = golden_space()
    target = workload.grid_best(space) * 0.99
    transfer = (TransferConfig(corpus_path=str(corpus_path), job_id=job_id)
                if corpus_path is not None else None)
    t0 = time.perf_counter()
    tuner = Tuner(workload, space,
                  TunerConfig(algorithm="bo", budget=budget, seed=seed,
                              verbose=False, parallelism=1,
                              transfer=transfer))
    h = tuner.run()
    tuner.close()
    t_target = evals_target = None
    for i, (t_done, v) in enumerate(workload.log):
        if v >= target:
            t_target = t_done - t0
            evals_target = i + 1
            break
    return h, t_target, evals_target


def run_transfer(budget: int = 40, seeds=(0, 1), emit=print):
    """The full corpus workflow; returns ``(rows, ok)``."""
    rows = []
    with tempfile.TemporaryDirectory() as d:
        corpus = pathlib.Path(d) / "corpus.json"

        # -- untimed warmup: populate the jitted GP bucket caches for both
        # the cold shapes and the transfer (prior-padded) shapes, so the
        # timed comparison never measures an XLA compile
        wa = job_a()
        _tune(wa, seed=0, budget=budget, corpus_path=corpus, job_id="warmup")
        wb = job_b()
        _tune(wb, seed=0, budget=budget, corpus_path=corpus,
              job_id="warmup-b")
        corpus.unlink()

        # -- job A: cold, recording into the corpus ------------------------
        a = job_a()
        h_a, t_a, n_a = _tune(a, seed=0, budget=budget,
                              corpus_path=corpus, job_id="job-A")
        n_recorded = len(json.loads(corpus.read_text()))
        rows.append({"mode": "corpus_populate", "job": "A",
                     "n_evals": len(h_a), "n_recorded": n_recorded,
                     "best": h_a.best().value})
        emit(f"transfer_corpus,A,evals={len(h_a)},recorded={n_recorded}")

        # -- job B: perturbed neighbor, cold vs warm, per seed -------------
        cold_t = cold_n = warm_t = warm_n = 0.0
        reached = True
        for seed in seeds:
            bc = job_b()
            _h, t_c, n_c = _tune(bc, seed=seed, budget=budget)
            bw = job_b()
            _h, t_w, n_w = _tune(bw, seed=seed, budget=budget,
                                 corpus_path=corpus,
                                 job_id=f"job-B-warm-{seed}")
            reached &= None not in (t_c, n_c, t_w, n_w)
            rows.append({"mode": "warm_vs_cold", "job": "B", "seed": seed,
                         "cold_seconds_to_target": t_c,
                         "cold_evals_to_target": n_c,
                         "warm_seconds_to_target": t_w,
                         "warm_evals_to_target": n_w})
            emit(f"transfer_b,seed={seed},cold_t="
                 f"{-1.0 if t_c is None else t_c:.3f},cold_n={n_c},"
                 f"warm_t={-1.0 if t_w is None else t_w:.3f},warm_n={n_w}")
            if reached:
                cold_t += t_c
                cold_n += n_c
                warm_t += t_w
                warm_n += n_w
        wall_ratio = cold_t / max(warm_t, 1e-9) if reached else 0.0
        eval_ratio = cold_n / max(warm_n, 1e-9) if reached else 0.0
        rows.append({"mode": "warm_vs_cold_total", "job": "B",
                     "seeds": list(seeds), "reached_target": reached,
                     "cold_seconds": cold_t, "warm_seconds": warm_t,
                     "cold_evals": cold_n, "warm_evals": warm_n,
                     "wall_clock_speedup": round(wall_ratio, 3),
                     "measurement_speedup": round(eval_ratio, 3)})
        emit(f"transfer_b_total,wall_speedup={wall_ratio:.2f}x,"
             f"eval_speedup={eval_ratio:.2f}x")
        ok_warm = reached and wall_ratio >= 2.0 and eval_ratio >= 2.0

        # -- job C: deliberately dissimilar — the corpus must not hurt -----
        cc = job_c()
        h_cc, t_cc, n_cc = _tune(cc, seed=0, budget=budget)
        cw = job_c()
        h_cw, t_cw, n_cw = _tune(cw, seed=0, budget=budget,
                                 corpus_path=corpus, job_id="job-C-warm")
        identical = h_cc.points() == h_cw.points()
        regression = ((n_cw / max(n_cc, 1)) if None not in (n_cc, n_cw)
                      else float("inf"))
        rows.append({"mode": "dissimilar_guard", "job": "C",
                     "cold_evals_to_target": n_cc,
                     "corpus_evals_to_target": n_cw,
                     "evals_regression": regression,
                     "traces_identical": identical})
        emit(f"transfer_c,cold_n={n_cc},corpus_n={n_cw},"
             f"identical={identical}")
        ok_dissimilar = identical and regression <= 1.05
    return rows, ok_warm, ok_dissimilar


def run_golden_check(emit=print):
    """No corpus configured => BO traces bit-for-bit equal to the pinned
    golden sequential traces.  Returns ``(rows, ok)``."""
    golden = json.loads(GOLDEN_PATH.read_text())
    space_dicts = golden["space"]

    def golden_objective(p):
        a, b, c = p["inter_op"], p["intra_op"], p["build"]
        return float(50.0 * pow(2.718281828, -((a - 11) / 5.0) ** 2)
                     + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)

    rows, ok = [], True
    for seed in (0, 3):
        trace = golden["traces"][f"bo:{seed}"]
        t = Tuner(golden_objective, SearchSpace.from_dicts(space_dicts),
                  TunerConfig(algorithm="bo", budget=18, seed=seed,
                              verbose=False, parallelism=1))
        h = t.run()
        t.close()
        match = h.points() == trace["points"]
        ok &= match
        rows.append({"mode": "golden_no_corpus", "algo": "bo", "seed": seed,
                     "bit_identical": match})
        emit(f"transfer_golden,bo,seed={seed},bit_identical={match}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=40)
    ap.add_argument("--out", default=None)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless warm-start beats cold 2x to "
                         "within-1%%-of-best (wall clock AND measurement "
                         "count), the dissimilar workload shows zero "
                         "regression, and the no-corpus golden traces stay "
                         "bit-for-bit (CI gate)")
    args = ap.parse_args(argv)
    failures = []
    rows, ok_warm, ok_dissimilar = run_transfer(budget=args.budget)
    if not ok_warm:
        failures.append(
            "transfer: warm-started job B did not reach within 1% of its "
            "grid optimum >= 2x faster than cold (wall clock and "
            "measurement count)")
    if not ok_dissimilar:
        failures.append(
            "transfer: the deliberately dissimilar job C regressed with "
            "the corpus configured (max-distance guard failed)")
    golden_rows, ok_golden = run_golden_check()
    rows += golden_rows
    if not ok_golden:
        failures.append(
            "transfer: BO golden sequential traces changed with no corpus "
            "configured (transfer machinery must be strictly additive)")
    if args.out:
        p = pathlib.Path(args.out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rows, indent=1))
    if args.check and failures:
        raise SystemExit("benchmark regression: " + "; ".join(failures))


if __name__ == "__main__":
    main()
