"""§Roofline table: reads the dry-run artifacts and emits the full
(arch x shape x mesh) roofline rows.

CSV rows: roofline,<arch>,<shape>,<mesh>,<compute_s>,<memory_s>,
          <collective_s>,<bottleneck>,<step_s>,<tput_tok_s>,<mfu>,
          <useful_ratio>,<mem_GB>,<fits>
"""
from __future__ import annotations

import argparse
import json
import pathlib

DEFAULT_ARTIFACT = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun_all.json"


def run(artifact=DEFAULT_ARTIFACT, emit=print):
    path = pathlib.Path(artifact)
    if not path.exists():
        emit(f"roofline,SKIPPED,artifact missing: {path} "
             "(run: python -m repro.launch.dryrun --all --out ...)")
        return []
    rows = []
    for rec in json.loads(path.read_text()):
        mesh = "multi" if rec.get("multi_pod") else "single"
        tag = f"{rec['arch']},{rec['shape']},{mesh}"
        if rec.get("skipped"):
            emit(f"roofline,{tag},SKIP,{rec['skip_reason']}")
            continue
        if "error" in rec:
            emit(f"roofline,{tag},ERROR,{rec['error']}")
            continue
        r = rec["roofline"]
        emit(
            f"roofline,{tag},{r['compute_s']:.4e},{r['memory_s']:.4e},"
            f"{r['collective_s']:.4e},{r['bottleneck']},{r['est_step_s']:.4e},"
            f"{r['throughput_tok_s']:.4g},{r['mfu']:.3f},"
            f"{r['useful_flops_ratio']:.3f},{r['mem_per_device_GB']:.2f},"
            f"{r['fits_hbm']}"
        )
        rows.append(rec)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=str(DEFAULT_ARTIFACT))
    args = ap.parse_args(argv)
    run(args.artifact)


if __name__ == "__main__":
    main()
