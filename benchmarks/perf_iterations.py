"""§Perf hillclimbing driver: evaluate named BackendConfig variants on a
cell and emit the hypothesis -> change -> before/after log rows.

    PYTHONPATH=src:. python -m benchmarks.perf_iterations --cell qwen2 \
        --out artifacts/perf_qwen2.json

Each variant is one hypothesis from the iteration loop (EXPERIMENTS.md
§Perf); the driver re-lowers + re-analyzes the cell per variant and
reports all three roofline terms + the dominant one.

``--microbench`` runs the batched ask/tell throughput micro-benchmark
instead: every engine tunes the same deterministic objective (with a
simulated per-measurement cost) at parallelism 1 vs N, emitting

    microbench,<algo>,<parallelism>,<best>,<wall_seconds>

so the speedup of the parallel evaluation executor is directly visible.

``--async-loop`` adds the completion-driven vs batch-barrier comparison
on a *skewed-cost* objective (a quarter of the grid is ~8x slower —
exactly the shape that stalls a barrier loop), plus the disk-backed
memo-cache check (a second identical tuning run must re-evaluate
nothing), plus the BO suggestion-overhead gate: after an untimed warmup
run compiles the bucketed GP shapes, the timed BO runs must trigger
**zero** new XLA compiles (compile-once surrogate contract; per-ask
suggestion latency and jit-cache-miss counts land in the emitted JSON).
``--remote`` adds the multi-host gate: two localhost ``launch/worker.py``
daemons serve the same skewed-cost objective and the remote executor
backend must be throughput-comparable to the thread backend at the same
parallelism, survive a mid-run worker kill with exactly-once accounting
(the dead worker's in-flight tasks are reinjected, never recorded as
config failures), and leave a memo (written by the tuner process — the
workers share no filesystem) that a thread-backend re-run fully reuses.

``--check`` turns all of these properties into exit-code gates, which
is what the CI ``bench-smoke`` job runs:

    python -m benchmarks.perf_iterations --microbench --async-loop \
        --multi-fidelity --remote --check --out BENCH_ci.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.tuning.parameters import BASELINE

# hypothesis text -> (variant name, BackendConfig overrides)
CELLS = {
    # worst roofline fraction (attention-dominated small model)
    "qwen2_train": {
        "arch": "qwen2-0.5b",
        "shape": "train_4k",
        "variants": [
            ("baseline(paper-faithful defaults)", {}),
            ("H1 causal tile pruning: attention flops ~2x down "
             "(kernel pl.when skip)", {"attn_prune": True}),
            ("H2 remat names instead of full: drop recompute flops ~1.25x, "
             "memory grows", {"attn_prune": True, "remat": "names"}),
            ("H3 microbatches=2: halve activation memory, amortized step",
             {"attn_prune": True, "microbatches": 2}),
            ("H4 wider DP (dp=64,tp=4): small model needs little TP; "
             "less collective, better matmul shapes",
             {"attn_prune": True, "microbatches": 2, "log2_dp": 6}),
            ("H5 pure DP (dp=256,tp=1) + fsdp for params",
             {"attn_prune": True, "microbatches": 2, "log2_dp": 8}),
        ],
    },
    # most collective-bound cell: GSPMD MoE all-gathers TBs per step
    "qwen3_moe_train": {
        "arch": "qwen3-moe-30b-a3b",
        "shape": "train_4k",
        "variants": [
            ("baseline(paper-faithful GSPMD dispatch)", {}),
            ("H1 shard_map expert parallelism: local dispatch + single bf16 "
             "psum combine -> collective bytes should drop ~100x",
             {"moe_impl": "ep_local"}),
            ("H2 + causal tile pruning (attention flops ~2x down)",
             {"moe_impl": "ep_local", "attn_prune": True}),
            ("H3 + microbatches=4 (fit HBM: activations /4)",
             {"moe_impl": "ep_local", "attn_prune": True, "microbatches": 4}),
            ("H4 + remat names (less recompute at some activation cost)",
             {"moe_impl": "ep_local", "attn_prune": True, "microbatches": 4,
              "remat": "names"}),
            ("H5 + capacity factor 1.0 (smaller expert buffers)",
             {"moe_impl": "ep_local", "attn_prune": True, "microbatches": 4,
              "capacity_factor": 1.0}),
        ],
    },
    # collective-bound serving: per-token KV all-gathers (seq-sharded cache)
    "deepseek_decode": {
        "arch": "deepseek-coder-33b",
        "shape": "decode_32k",
        "variants": [
            ("baseline(paper-faithful defaults)", {}),
            ("H1 bf16 serving weights: halve weight footprint + reads",
             {"serve_bf16_params": True}),
            ("H2 + cache sharded by kv-heads (attention shard-local; "
             "needs tp<=8 for kv=8): dp=32,tp=8",
             {"serve_bf16_params": True, "cache_shard": "heads",
              "log2_dp": 5}),
            ("H3 + dp=16,tp=16 with head-sharded cache (kv 8%%16!=0 -> "
             "falls back to replicated cache: refutation probe)",
             {"serve_bf16_params": True, "cache_shard": "heads"}),
        ],
    },
}


def run(cell_key: str, emit=print, multi_pod: bool = False):
    from repro.launch.dryrun import analyze_cell

    cell = CELLS[cell_key]
    rows = []
    for label, overrides in cell["variants"]:
        bc = BASELINE.replace(**overrides)
        rec = analyze_cell(cell["arch"], cell["shape"], multi_pod=multi_pod,
                           bc=bc)
        r = rec["roofline"]
        row = {
            "cell": cell_key, "variant": label, "overrides": overrides,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "est_step_s": r["est_step_s"],
            "throughput": r["throughput_tok_s"], "mfu": r["mfu"],
            "mem_GB": r["mem_per_device_GB"], "fits": r["fits_hbm"],
        }
        rows.append(row)
        emit(f"perf,{cell_key},\"{label}\",{r['compute_s']:.4f},"
             f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['bottleneck']},"
             f"{r['est_step_s']:.4f},{r['throughput_tok_s']:.4g},"
             f"{r['mfu']:.3f},{r['mem_per_device_GB']:.1f},{r['fits_hbm']}")
    return rows


def _bench_value(p) -> float:
    """The shared synthetic tuning landscape (max ~84 at inter_op=11,
    intra_op=60, build=3) — one definition so every gated benchmark and
    its margins measure the same objective."""
    a, b, c = p["inter_op"], p["intra_op"], p["build"]
    return float(50.0 * 2.718281828 ** (-((a - 11) / 5.0) ** 2)
                 + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)


def _bench_space():
    from repro.core import CatDim, IntDim, SearchSpace
    return SearchSpace([IntDim("inter_op", 1, 16),
                        IntDim("intra_op", 0, 60, 5),
                        CatDim("build", (1, 2, 3))])


# skewed-cost parameters shared by the async and remote comparisons
_SKEW_FAST_S, _SKEW_SLOW_S = 0.02, 0.16


def _skewed_sleep_value(p, fast_s=_SKEW_FAST_S, slow_s=_SKEW_SLOW_S):
    time.sleep(slow_s if (p["inter_op"] + p["intra_op"]) % 4 == 0 else fast_s)
    return _bench_value(p)


def make_remote_bench_objective():
    """Factory the worker daemons import (--objective ...:name()): the
    same skewed-cost objective the local comparisons tune, built ON the
    worker so nothing but points and results crosses the wire."""
    from repro.tuning.objective import Evaluator

    class SkewedBenchObjective(Evaluator):
        def __call__(self, p, fidelity=None):
            v = _skewed_sleep_value(p)
            return v, {"cost_seconds":
                       _SKEW_SLOW_S if (p["inter_op"] + p["intra_op"]) % 4
                       == 0 else _SKEW_FAST_S}

    return SkewedBenchObjective()


def run_microbench(budget: int = 24, parallelism: int = 4,
                   eval_seconds: float = 0.05, emit=print):
    """Batched ask/tell vs sequential loop on a deterministic objective.

    The objective's value is a pure function of the point; the sleep
    stands in for measurement cost (a real harness blocks on compile +
    run, releasing the GIL, which is exactly what the thread-pool
    executor overlaps).  Returns rows of
    ``(algo, parallelism, best, seconds)``.
    """
    from repro.core import Tuner, TunerConfig

    def objective(p):
        time.sleep(eval_seconds)
        return _bench_value(p)

    make_space = _bench_space
    rows = []
    # same iteration budget: the executor should cut wall-clock ~par-fold
    for algo in ["bo", "ga", "nms", "random", "exhaustive"]:
        for par in (1, parallelism):
            t = Tuner(objective, make_space(),
                      TunerConfig(algorithm=algo, budget=budget, seed=0,
                                  verbose=False, parallelism=par))
            t0 = time.perf_counter()
            h = t.run()
            secs = time.perf_counter() - t0
            t.close()
            rows.append({"mode": "iteration_budget", "algo": algo,
                         "parallelism": par, "best": h.best().value,
                         "seconds": secs})
            emit(f"microbench,{algo},{par},{h.best().value:.4f},{secs:.3f}")
    # same wall-clock budget (the real production constraint): the parallel
    # executor measures ~par times more configurations in the same seconds
    wall = budget * eval_seconds / 2
    for algo in ["bo", "ga", "nms", "random"]:
        for par in (1, parallelism):
            t = Tuner(objective, make_space(),
                      TunerConfig(algorithm=algo, budget=10**9, seed=0,
                                  verbose=False, parallelism=par,
                                  wall_clock_budget=wall))
            h = t.run()
            t.close()
            rows.append({"mode": "wall_clock_budget", "algo": algo,
                         "parallelism": par, "best": h.best().value,
                         "n_evals": len(h), "wall_clock_s": wall})
            emit(f"microbench_wallclock,{algo},{par},"
                 f"{h.best().value:.4f},{len(h)}")
    return rows


def run_async_comparison(budget: int = 16, parallelism: int = 4,
                         fast_s: float = 0.02, slow_s: float = 0.16,
                         emit=print):
    """Completion-driven loop vs batch-barrier loop on a skewed-cost
    objective, plus the disk-backed memo-cache re-evaluation check.

    About a quarter of the grid costs ``slow_s`` and the rest ``fast_s``;
    a barrier loop pays ~``slow_s`` for every batch containing one slow
    point while the async loop keeps its other workers cycling, so at the
    same iteration budget the async loop should win on wall clock.
    Returns ``(rows, ok)`` where ``ok`` is the CI gate: async total
    beats the batch total AND a second identical tuning run re-evaluates
    nothing AND the timed BO runs trigger zero new XLA compiles after
    the warmup run has populated the bucketed jit cache.
    """
    import tempfile

    from repro.core import Tuner, TunerConfig
    from repro.core import gp as gp_module
    from repro.tuning.objective import CountingEvaluator

    def objective(p):
        time.sleep(slow_s if (p["inter_op"] + p["intra_op"]) % 4 == 0
                   else fast_s)
        return _bench_value(p)

    make_space = _bench_space

    # BO is gated too since the compile-once surrogate bounded its
    # suggestion overhead (bucketed/padded GP shapes + fused jitted
    # acquisition): after the warmup run below populates the jit cache,
    # a per-completion GP refresh costs milliseconds, not an XLA
    # compile.  The warmup run is untimed so the comparison measures
    # loop scheduling + steady-state suggestion cost, never one-time
    # compiles; the compile-once contract is then enforced by asserting
    # the timed BO runs add zero jit-cache entries.
    gated = ("bo", "ga", "nms", "random")
    warm = Tuner(objective, make_space(),
                 TunerConfig(algorithm="bo", budget=budget, seed=0,
                             verbose=False, parallelism=parallelism))
    warm.run()
    warm.close()
    entries_after_warmup = gp_module.jit_cache_entries()
    rows, totals, bo_recompiles = [], {"batch": 0.0, "async": 0.0}, 0
    for algo in ["bo", "ga", "nms", "random"]:
        for loop in ("batch", "async"):
            t = Tuner(objective, make_space(),
                      TunerConfig(algorithm=algo, budget=budget, seed=0,
                                  verbose=False, parallelism=parallelism,
                                  loop=loop))
            t0 = time.perf_counter()
            h = t.run()
            secs = time.perf_counter() - t0
            t.close()
            if algo in gated:
                totals[loop] += secs
            rows.append({"mode": "async_vs_batch", "algo": algo, "loop": loop,
                         "parallelism": parallelism, "best": h.best().value,
                         "n_evals": len(h), "seconds": secs,
                         "gated": algo in gated})
            emit(f"asyncbench,{algo},{loop},{parallelism},"
                 f"{h.best().value:.4f},{secs:.3f}")
            if algo == "bo":
                ask_s = t.engine.ask_seconds
                misses = t.engine.jit_misses
                bo_recompiles += sum(misses)
                rows.append({
                    "mode": "bo_suggestion_overhead", "loop": loop,
                    "per_ask_seconds": [round(s, 5) for s in ask_s],
                    "jit_cache_misses": misses,
                    "mean_ask_seconds": sum(ask_s) / max(len(ask_s), 1),
                    "max_ask_seconds": max(ask_s, default=0.0),
                })
                emit(f"bo_suggestion,{loop},asks={len(ask_s)},"
                     f"mean={sum(ask_s) / max(len(ask_s), 1) * 1e3:.1f}ms,"
                     f"recompiles={sum(misses)}")
    rows.append({"mode": "bo_jit_cache",
                 "entries_after_warmup": entries_after_warmup,
                 "recompiles_after_warmup": bo_recompiles})
    emit(f"bo_jit_cache,entries={entries_after_warmup},"
         f"recompiles_after_warmup={bo_recompiles}")
    speedup = totals["batch"] / max(totals["async"], 1e-9)
    rows.append({"mode": "async_vs_batch_total", "gated_algos": list(gated),
                 "batch_seconds": totals["batch"],
                 "async_seconds": totals["async"], "speedup": speedup})
    emit(f"asyncbench_total({'+'.join(gated)}),batch={totals['batch']:.3f},"
         f"async={totals['async']:.3f},speedup={speedup:.2f}x")

    # second run of the same tuning job must hit the disk memo: 0 re-evals
    counting = CountingEvaluator(objective)
    with tempfile.TemporaryDirectory() as d:
        memo = str(pathlib.Path(d) / "memo.json")

        def run_once():
            t = Tuner(counting, make_space(),
                      TunerConfig(algorithm="random", budget=budget, seed=0,
                                  verbose=False, parallelism=1,
                                  memo_cache_path=memo))
            h = t.run()
            t.close()
            return h

        run_once()
        first = counting.calls
        run_once()
        re_evals = counting.calls - first
    rows.append({"mode": "memo_cache_second_run",
                 "first_run_evals": first, "second_run_re_evals": re_evals})
    emit(f"memocache,first={first},second_run_re_evals={re_evals}")

    # regression gate, not a race: a 10% tolerance absorbs scheduling noise
    # on loaded CI runners while still catching a real loss of the async
    # loop's ~1.5x structural win (the emitted speedup shows the margin);
    # the recompile gate has no tolerance — compile-once is exact
    ok = (totals["async"] < totals["batch"] * 1.1 and re_evals == 0
          and bo_recompiles == 0)
    return rows, ok


def run_multi_fidelity_comparison(budget: int = 20, parallelism: int = 4,
                                  fast_s: float = 0.04, slow_s: float = 0.32,
                                  emit=print):
    """Successive-halving (ASHA rungs + preemption) vs the full-fidelity
    async loop on the skewed-cost objective.

    Both runs spend the same logical budget (``budget`` full-measurement
    equivalents).  The multi-fidelity run screens at 1/9 cost and
    promotes the top third per rung, so it should complete a
    full-fidelity measurement within 1% of the full run's best value in
    well under half the full run's wall clock — that ratio is the CI
    gate, together with exactly-once accounting under preemption: every
    real objective call is recorded exactly once (nothing lost when a
    preempt lands after a worker started, nothing double-recorded when
    it is cancelled first).

    Low fidelity is simulated honestly: cost scales with fidelity and
    the value carries a deterministic point-dependent bias that shrinks
    as fidelity rises, so promotion decisions are made on noisy
    rankings, exactly like short-run measurements in the paper's
    harness.
    """
    from repro.core import Tuner, TunerConfig
    from repro.tuning.objective import Evaluator

    true_value = _bench_value

    class SkewedFidelityObjective(Evaluator):
        supports_fidelity = True

        def __init__(self):
            self.log = []  # (t_done, key, fidelity, value) per real call

        def __call__(self, p, fidelity=None):
            f = 1.0 if fidelity is None else float(fidelity)
            base = slow_s if (p["inter_op"] + p["intra_op"]) % 4 == 0 else fast_s
            time.sleep(base * f)
            v = true_value(p)
            # deterministic measurement bias, shrinking with fidelity
            wiggle = ((p["inter_op"] * 13 + p["intra_op"] * 7
                       + p["build"] * 3) % 9 - 4) / 2.0
            v += (1.0 - f) * wiggle
            key = (p["inter_op"], p["intra_op"], p["build"])
            self.log.append((time.perf_counter(), key, f, v))
            # declared cost: the simulated measurement is the cost model's
            # training signal and must stay deterministic
            return v, {"cost_seconds": base * f}

    make_space = _bench_space

    # -- full-fidelity reference run -----------------------------------------
    full_obj = SkewedFidelityObjective()
    t_full = Tuner(full_obj, make_space(),
                   TunerConfig(algorithm="random", budget=budget, seed=0,
                               verbose=False, parallelism=parallelism))
    t0 = time.perf_counter()
    h_full = t_full.run()
    full_seconds = time.perf_counter() - t0
    t_full.close()
    best_full = h_full.best().value

    # -- successive-halving run, same logical budget -------------------------
    mf_obj = SkewedFidelityObjective()
    t_mf = Tuner(mf_obj, make_space(),
                 TunerConfig(algorithm="random", budget=budget, seed=0,
                             verbose=False, parallelism=parallelism,
                             multi_fidelity=True))
    t0 = time.perf_counter()
    h_mf = t_mf.run()
    mf_seconds = time.perf_counter() - t0
    rungs = t_mf.rung_scheduler.stats()
    t_mf.close()

    # time-to-target: first *full-fidelity* measurement within 1% of the
    # full run's best value (partial values are biased by construction and
    # do not count as "reached")
    target = best_full - 0.01 * abs(best_full)
    t_target = None
    for t_done, _key, f, v in sorted(mf_obj.log):
        if f >= 1.0 and v >= target:
            t_target = t_done - t0
            break

    # exactly-once accounting under preemption: every real measurement is
    # recorded exactly once — no losses (a preempt landing after the worker
    # started must still record) and no double-records (a cancelled preempt
    # must record nothing)
    measured = [e for e in h_mf.evals if not e.meta.get("memoized")]
    lost = len(mf_obj.log) - len(measured)
    seen_keys = [( *(e.point[k] for k in ("inter_op", "intra_op", "build")),
                  round(e.fidelity, 9)) for e in measured]
    double = len(seen_keys) - len(set(seen_keys))

    ratio = (t_target / full_seconds) if t_target is not None else float("inf")
    ok = t_target is not None and ratio <= 0.5 and lost == 0 and double == 0
    rows = [{
        "mode": "multi_fidelity", "algo": "random",
        "parallelism": parallelism, "budget_full_equivalents": budget,
        "full_best": best_full, "full_seconds": full_seconds,
        # None when nothing reached the top rung — the ratio gate then
        # fails cleanly (t_target stays None) instead of crashing here
        "mf_best_full_fidelity": max(
            (v for _t, _k, f, v in mf_obj.log if f >= 1.0), default=None),
        "mf_measurements": len(measured), "mf_seconds": mf_seconds,
        "time_to_within_1pct_s": t_target,
        "time_to_target_ratio": None if t_target is None else round(ratio, 4),
        "lost_results": lost, "double_recorded": double,
        "rungs": rungs,
    }]
    emit(f"mfbench,random,{parallelism},best_full={best_full:.4f},"
         f"full_s={full_seconds:.3f},t_target="
         f"{-1.0 if t_target is None else t_target:.3f},"
         f"ratio={ratio:.3f},lost={lost},double={double}")
    for row in rungs:
        emit(f"mfrung,{row['rung']},fidelity={row['fidelity']},"
             f"started={row['started']},completed={row['completed']},"
             f"promoted={row['promoted']},preempted={row['preempted']}")
    return rows, ok


def run_remote_comparison(budget: int = 16, parallelism: int = 4,
                          emit=print):
    """The remote executor backend against two real localhost worker
    daemons (subprocesses of ``launch/worker.py``), gated three ways:

    * **throughput** — completion-driven scaling over the fleet (2
      workers x 2 slots = the thread backend's parallelism) must be
      comparable to the thread backend on the same skewed-cost
      objective (RPC overhead is per-message milliseconds; the gate
      allows 1.5x plus a small absolute cushion for connection setup
      noise on loaded CI runners);
    * **worker kill mid-run** — one worker is killed while measurements
      are in flight; its tasks must be reinjected onto the survivor
      (never recorded as config failures), the run must still complete
      the full budget, and accounting must be exactly-once: nothing
      lost, nothing double-recorded, every recorded value bit-equal to
      the deterministic objective;
    * **shared memo across backends** — the memo written by the remote
      run (by the *tuner* process: workers share no filesystem with the
      store) must drive a second identical run on the local thread
      backend to zero re-evaluations.

    Returns ``(rows, ok)``.
    """
    import os
    import socket
    import subprocess
    import sys
    import tempfile
    import threading

    from repro.core import Tuner, TunerConfig
    from repro.tuning.objective import CountingEvaluator

    def objective(p):  # local twin of the worker-side objective
        return _skewed_sleep_value(p)

    make_space = _bench_space
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root)]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])

    def free_port():
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
        s.close()
        return port

    def spawn_worker(port):
        return subprocess.Popen(
            [sys.executable, "-m", "repro.launch.worker",
             "--host", "127.0.0.1", "--port", str(port),
             "--slots", "2", "--heartbeat", "0.5", "--objective",
             "benchmarks.perf_iterations:make_remote_bench_objective()"],
            env=env, cwd=str(root),
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)

    ports = [free_port() for _ in range(3)]
    workers = [spawn_worker(p) for p in ports]  # third = the kill victim
    rows = []
    point_key = ("inter_op", "intra_op", "build")
    try:
        with tempfile.TemporaryDirectory() as d:
            memo_clean = str(pathlib.Path(d) / "memo_remote.json")
            memo_kill = str(pathlib.Path(d) / "memo_kill.json")

            # -- thread-backend reference at the same parallelism ---------
            t = Tuner(objective, make_space(),
                      TunerConfig(algorithm="random", budget=budget, seed=0,
                                  verbose=False, parallelism=parallelism))
            t0 = time.perf_counter()
            h_thread = t.run()
            thread_s = time.perf_counter() - t0
            t.close()

            # -- clean remote runs: 2 workers x 2 slots.  Timed twice
            # (fresh memo each, so nothing is a cache hit) and gated on
            # the best: with 4+ processes on a small CI runner a single
            # timing can eat an arbitrary scheduling stall, and the gate
            # asks whether the backend CAN match the thread backend, not
            # whether the runner was quiet.
            remote_timings = []
            for memo_path in (memo_clean,
                              str(pathlib.Path(d) / "memo_remote2.json")):
                t = Tuner(objective, make_space(),
                          TunerConfig(algorithm="random", budget=budget,
                                      seed=0, verbose=False,
                                      memo_cache_path=memo_path,
                                      workers=[f"127.0.0.1:{ports[0]}",
                                               f"127.0.0.1:{ports[1]}"]))
                fleet_par = t.executor.parallelism
                t0 = time.perf_counter()
                h_remote = t.run()
                remote_timings.append(time.perf_counter() - t0)
                t.close()
            remote_s = min(remote_timings)
            ratio = remote_s / max(thread_s, 1e-9)
            remote_exact = all(e.value == _bench_value(e.point)
                               for e in h_remote.evals)
            rows.append({"mode": "remote_vs_thread", "algo": "random",
                         "parallelism": parallelism,
                         "fleet_parallelism": fleet_par,
                         "thread_seconds": thread_s,
                         "remote_seconds": remote_s,
                         "remote_timings": [round(s, 4)
                                            for s in remote_timings],
                         "ratio": round(ratio, 4),
                         "n_evals": len(h_remote),
                         "values_exact": remote_exact,
                         "best_thread": h_thread.best().value,
                         "best_remote": h_remote.best().value})
            emit(f"remotebench,random,{parallelism},thread={thread_s:.3f},"
                 f"remote={remote_s:.3f},ratio={ratio:.2f}")

            # -- worker kill mid-run: reinjection + exactly-once ----------
            t = Tuner(objective, make_space(),
                      TunerConfig(algorithm="random", budget=budget, seed=0,
                                  verbose=False, memo_cache_path=memo_kill,
                                  workers=[f"127.0.0.1:{ports[0]}",
                                           f"127.0.0.1:{ports[2]}"]))
            # kill once the memo proves the run is underway (>= 2 results
            # flushed): deterministic mid-run, unlike a wall-clock timer
            def kill_when_underway():
                give_up = time.time() + 30
                while time.time() < give_up:
                    try:
                        if len(json.loads(
                                pathlib.Path(memo_kill).read_text())) >= 2:
                            break
                    except (OSError, json.JSONDecodeError):
                        pass
                    time.sleep(0.01)
                workers[2].kill()

            killer = threading.Thread(target=kill_when_underway, daemon=True)
            killer.start()
            t0 = time.perf_counter()
            h_kill = t.run()
            kill_run_s = time.perf_counter() - t0
            t.close()
            killer.join(timeout=35)
            measured = [e for e in h_kill.evals
                        if not e.meta.get("memoized")]
            keys = [tuple(e.point[k] for k in point_key) for e in measured]
            kill_lost = budget - len(h_kill)
            kill_double = len(keys) - len(set(keys))
            kill_exact = all(e.value == _bench_value(e.point)
                             for e in h_kill.evals)
            worker_was_killed = workers[2].poll() is not None
            rows.append({"mode": "remote_worker_kill",
                         "kill_run_seconds": round(kill_run_s, 3),
                         "worker_was_killed": worker_was_killed,
                         "n_evals": len(h_kill), "lost": kill_lost,
                         "double_recorded": kill_double,
                         "values_exact": kill_exact})
            emit(f"remotekill,killed={worker_was_killed},"
                 f"n={len(h_kill)},lost={kill_lost},double={kill_double},"
                 f"exact={kill_exact}")

            # -- memo written by the tuner host, honored across backends --
            counting = CountingEvaluator(objective)
            t = Tuner(counting, make_space(),
                      TunerConfig(algorithm="random", budget=budget, seed=0,
                                  verbose=False, parallelism=parallelism,
                                  memo_cache_path=memo_clean))
            h_memo = t.run()
            t.close()
            rows.append({"mode": "remote_memo_cross_backend",
                         "second_run_re_evals": counting.calls,
                         "n_evals": len(h_memo)})
            emit(f"remotememo,second_run_re_evals={counting.calls}")

        ok = (remote_s <= thread_s * 1.5 + 0.25
              and remote_exact
              and worker_was_killed  # else the kill gate proved nothing
              and kill_lost == 0 and kill_double == 0 and kill_exact
              and counting.calls == 0)
        return rows, ok
    finally:
        for w in workers:
            if w.poll() is None:
                w.kill()
        for w in workers:
            w.wait(timeout=10)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbench", action="store_true",
                    help="run the ask/tell parallel-executor micro-benchmark")
    ap.add_argument("--async-loop", action="store_true",
                    help="add the completion-driven vs batch-barrier "
                         "comparison + memo-cache re-evaluation check")
    ap.add_argument("--multi-fidelity", action="store_true",
                    help="add the successive-halving vs full-fidelity "
                         "time-to-target comparison + exactly-once "
                         "preemption accounting check (runs at "
                         "max(--budget, 20) full-measurement equivalents: "
                         "smaller budgets leave too few rung completions "
                         "for a stable gate)")
    ap.add_argument("--remote", action="store_true",
                    help="add the remote-executor gate: two localhost "
                         "worker daemons vs the thread backend at the same "
                         "parallelism, a mid-run worker kill (reinjection + "
                         "exactly-once accounting), and the memo shared "
                         "across backends")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if the async loop does not beat the "
                         "batch loop, the memo cache re-evaluates, BO "
                         "recompiles after warmup, successive halving "
                         "misses its time-to-target / accounting gates, or "
                         "the remote backend misses its throughput / "
                         "exactly-once / shared-memo gates (CI gate)")
    ap.add_argument("--parallelism", type=int, default=4)
    ap.add_argument("--budget", type=int, default=24)
    args = ap.parse_args(argv)
    ok = True
    failures = []
    if args.microbench or args.async_loop or args.multi_fidelity \
            or args.remote:
        rows = []
        if args.microbench:
            rows += run_microbench(budget=args.budget,
                                   parallelism=args.parallelism)
        if args.async_loop:
            async_rows, ok_async = run_async_comparison(
                budget=min(args.budget, 16), parallelism=args.parallelism)
            rows += async_rows
            if not ok_async:
                failures.append(
                    "async-loop: completion-driven loop did not beat the "
                    "batch barrier, the memo cache re-evaluated, or the BO "
                    "surrogate recompiled after warmup (compile-once "
                    "contract)")
        if args.multi_fidelity:
            mf_budget = max(args.budget, 20)
            if mf_budget != args.budget:
                print(f"mfbench_note,budget_floored,{args.budget}->"
                      f"{mf_budget} (gate needs enough rung completions)")
            mf_rows, ok_mf = run_multi_fidelity_comparison(
                budget=mf_budget, parallelism=args.parallelism)
            rows += mf_rows
            if not ok_mf:
                failures.append(
                    "multi-fidelity: successive halving did not reach within "
                    "1% of the full-fidelity best in <= 0.5x its wall clock, "
                    "or preemption lost/double-recorded a result")
        if args.remote:
            remote_rows, ok_remote = run_remote_comparison(
                budget=min(args.budget, 16), parallelism=args.parallelism)
            rows += remote_rows
            if not ok_remote:
                failures.append(
                    "remote: the two-worker fleet was not throughput-"
                    "comparable to the thread backend, a mid-run worker "
                    "kill lost or double-recorded a result, or the memo "
                    "written by the remote run was not honored by a "
                    "thread-backend re-run")
        ok = not failures
    else:
        if not args.cell:
            ap.error("--cell is required unless --microbench, --async-loop, "
                     "--multi-fidelity or --remote is given")
        rows = run(args.cell, multi_pod=args.multi_pod)
    if args.out:
        p = pathlib.Path(args.out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rows, indent=1))
    if args.check and not ok:
        raise SystemExit("benchmark regression: " + "; ".join(failures))


if __name__ == "__main__":
    main()
