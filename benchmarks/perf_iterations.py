"""§Perf hillclimbing driver: evaluate named BackendConfig variants on a
cell and emit the hypothesis -> change -> before/after log rows.

    PYTHONPATH=src:. python -m benchmarks.perf_iterations --cell qwen2 \
        --out artifacts/perf_qwen2.json

Each variant is one hypothesis from the iteration loop (EXPERIMENTS.md
§Perf); the driver re-lowers + re-analyzes the cell per variant and
reports all three roofline terms + the dominant one.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.tuning.parameters import BASELINE

# hypothesis text -> (variant name, BackendConfig overrides)
CELLS = {
    # worst roofline fraction (attention-dominated small model)
    "qwen2_train": {
        "arch": "qwen2-0.5b",
        "shape": "train_4k",
        "variants": [
            ("baseline(paper-faithful defaults)", {}),
            ("H1 causal tile pruning: attention flops ~2x down "
             "(kernel pl.when skip)", {"attn_prune": True}),
            ("H2 remat names instead of full: drop recompute flops ~1.25x, "
             "memory grows", {"attn_prune": True, "remat": "names"}),
            ("H3 microbatches=2: halve activation memory, amortized step",
             {"attn_prune": True, "microbatches": 2}),
            ("H4 wider DP (dp=64,tp=4): small model needs little TP; "
             "less collective, better matmul shapes",
             {"attn_prune": True, "microbatches": 2, "log2_dp": 6}),
            ("H5 pure DP (dp=256,tp=1) + fsdp for params",
             {"attn_prune": True, "microbatches": 2, "log2_dp": 8}),
        ],
    },
    # most collective-bound cell: GSPMD MoE all-gathers TBs per step
    "qwen3_moe_train": {
        "arch": "qwen3-moe-30b-a3b",
        "shape": "train_4k",
        "variants": [
            ("baseline(paper-faithful GSPMD dispatch)", {}),
            ("H1 shard_map expert parallelism: local dispatch + single bf16 "
             "psum combine -> collective bytes should drop ~100x",
             {"moe_impl": "ep_local"}),
            ("H2 + causal tile pruning (attention flops ~2x down)",
             {"moe_impl": "ep_local", "attn_prune": True}),
            ("H3 + microbatches=4 (fit HBM: activations /4)",
             {"moe_impl": "ep_local", "attn_prune": True, "microbatches": 4}),
            ("H4 + remat names (less recompute at some activation cost)",
             {"moe_impl": "ep_local", "attn_prune": True, "microbatches": 4,
              "remat": "names"}),
            ("H5 + capacity factor 1.0 (smaller expert buffers)",
             {"moe_impl": "ep_local", "attn_prune": True, "microbatches": 4,
              "capacity_factor": 1.0}),
        ],
    },
    # collective-bound serving: per-token KV all-gathers (seq-sharded cache)
    "deepseek_decode": {
        "arch": "deepseek-coder-33b",
        "shape": "decode_32k",
        "variants": [
            ("baseline(paper-faithful defaults)", {}),
            ("H1 bf16 serving weights: halve weight footprint + reads",
             {"serve_bf16_params": True}),
            ("H2 + cache sharded by kv-heads (attention shard-local; "
             "needs tp<=8 for kv=8): dp=32,tp=8",
             {"serve_bf16_params": True, "cache_shard": "heads",
              "log2_dp": 5}),
            ("H3 + dp=16,tp=16 with head-sharded cache (kv 8%%16!=0 -> "
             "falls back to replicated cache: refutation probe)",
             {"serve_bf16_params": True, "cache_shard": "heads"}),
        ],
    },
}


def run(cell_key: str, emit=print, multi_pod: bool = False):
    from repro.launch.dryrun import analyze_cell

    cell = CELLS[cell_key]
    rows = []
    for label, overrides in cell["variants"]:
        bc = BASELINE.replace(**overrides)
        rec = analyze_cell(cell["arch"], cell["shape"], multi_pod=multi_pod,
                           bc=bc)
        r = rec["roofline"]
        row = {
            "cell": cell_key, "variant": label, "overrides": overrides,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "est_step_s": r["est_step_s"],
            "throughput": r["throughput_tok_s"], "mfu": r["mfu"],
            "mem_GB": r["mem_per_device_GB"], "fits": r["fits_hbm"],
        }
        rows.append(row)
        emit(f"perf,{cell_key},\"{label}\",{r['compute_s']:.4f},"
             f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['bottleneck']},"
             f"{r['est_step_s']:.4f},{r['throughput_tok_s']:.4g},"
             f"{r['mfu']:.3f},{r['mem_per_device_GB']:.1f},{r['fits_hbm']}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = run(args.cell, multi_pod=args.multi_pod)
    if args.out:
        p = pathlib.Path(args.out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
