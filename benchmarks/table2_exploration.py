"""Paper Table 2: per-parameter sampled-range coverage per algorithm.

For each workload x algorithm, runs the 50-iteration tuning and reports
the (min,max) of sampled values vs the tunable range, as a percentage —
the paper's exploration/exploitation diagnostic (BO ~100%, GA <50%, NMS
between).

CSV rows: table2,<workload>,<algo>,<param>,<coverage_pct>
          table2_mean,<algo>,<mean_coverage_pct>
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.workloads import MEASURED_WORKLOADS, surrogate_objective
from repro.core import SearchSpace, Tuner, TunerConfig

ALGOS = ("bo", "ga", "nms")


def run(budget: int = 50, emit=print):
    per_algo = {a: [] for a in ALGOS}
    for w in MEASURED_WORKLOADS:
        space = SearchSpace.from_dicts(w["space"])
        obj = surrogate_objective(w)
        for algo in ALGOS:
            t = Tuner(obj, space, TunerConfig(algorithm=algo, budget=budget,
                                              seed=0, verbose=False))
            h = t.run()
            fr = h.sampled_range_fraction()
            for name, f in fr.items():
                emit(f"table2,{w['name']},{algo},{name},{100*f:.0f}")
                per_algo[algo].append(f)
    means = {}
    for algo in ALGOS:
        means[algo] = float(np.mean(per_algo[algo]))
        emit(f"table2_mean,{algo},{100*means[algo]:.1f}")
    return means


def main(argv=None):
    argparse.ArgumentParser().parse_args(argv)
    run()


if __name__ == "__main__":
    main()
