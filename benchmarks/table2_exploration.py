"""Paper Table 2: per-parameter sampled-range coverage per algorithm.

For each workload x algorithm, runs the 50-iteration tuning and reports
the (min,max) of sampled values vs the tunable range, as a percentage —
the paper's exploration/exploitation diagnostic (BO ~100%, GA <50%, NMS
between).

CSV rows: table2,<workload>,<algo>,<param>,<coverage_pct>
          table2_mean,<algo>,<mean_coverage_pct>

``--scheduler asha,hyperband,pbt`` reports the same coverage diagnostic
with the *trial scheduler* varied instead of the search engine (one
engine, the schedulers' different budget allocation — early-stopping
ladders vs mutating populations — is what moves coverage):

    table2_sched,<workload>,<scheduler>,<param>,<coverage_pct>
    table2_sched_mean,<scheduler>,<mean_coverage_pct>
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.workloads import MEASURED_WORKLOADS, surrogate_objective
from repro.core import MultiFidelityConfig, SearchSpace, Tuner, TunerConfig

ALGOS = ("bo", "ga", "nms")


def run(budget: int = 50, emit=print):
    per_algo = {a: [] for a in ALGOS}
    for w in MEASURED_WORKLOADS:
        space = SearchSpace.from_dicts(w["space"])
        obj = surrogate_objective(w)
        for algo in ALGOS:
            t = Tuner(obj, space, TunerConfig(algorithm=algo, budget=budget,
                                              seed=0, verbose=False))
            h = t.run()
            fr = h.sampled_range_fraction()
            for name, f in fr.items():
                emit(f"table2,{w['name']},{algo},{name},{100*f:.0f}")
                per_algo[algo].append(f)
    means = {}
    for algo in ALGOS:
        means[algo] = float(np.mean(per_algo[algo]))
        emit(f"table2_mean,{algo},{100*means[algo]:.1f}")
    return means


def run_schedulers(schedulers, budget: int = 50, emit=print):
    from benchmarks.fig5_tuning_curves import FidelitySurrogate

    per_kind = {k: [] for k in schedulers}
    for w in MEASURED_WORKLOADS:
        space = SearchSpace.from_dicts(w["space"])
        for kind in schedulers:
            obj = FidelitySurrogate(surrogate_objective(w))
            t = Tuner(obj, space,
                      TunerConfig(algorithm="random", budget=budget, seed=0,
                                  verbose=False,
                                  multi_fidelity=MultiFidelityConfig(
                                      enabled=True, scheduler=kind,
                                      min_fidelity=1 / 9, eta=3)))
            h = t.run()
            t.close()
            for name, f in h.sampled_range_fraction().items():
                emit(f"table2_sched,{w['name']},{kind},{name},{100*f:.0f}")
                per_kind[kind].append(f)
    means = {}
    for kind in schedulers:
        means[kind] = float(np.mean(per_kind[kind]))
        emit(f"table2_sched_mean,{kind},{100*means[kind]:.1f}")
    return means


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default=None,
                    help="comma-separated trial schedulers to compare "
                         "(asha,hyperband,pbt) instead of the search-"
                         "engine comparison")
    args = ap.parse_args(argv)
    if args.scheduler:
        kinds = [k.strip() for k in args.scheduler.split(",") if k.strip()]
        return run_schedulers(kinds)
    run()


if __name__ == "__main__":
    main()
