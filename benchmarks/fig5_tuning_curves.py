"""Paper Fig. 5: tuning curves — BO vs GA vs NMS on every workload.

Default: surrogate objective, 50 iterations, 3 seeds (seconds).
``--measured``: real wall-clock measurement of each configuration on the
local device (the paper's harness; minutes).  CSV rows:

    fig5,<workload>,<algo>,<seed>,<iter>,<best_so_far>
    fig5_final,<workload>,<algo>,<mean_best>,<std_best>

``--scheduler asha,hyperband,pbt`` switches to the *scheduler*
comparison on the same substrate: one search engine, the trial
scheduler varied, best-so-far charted against logical budget spend.
CSV rows mirror the algorithm mode:

    fig5_sched,<workload>,<scheduler>,<seed>,<iter>,<best_so_far>
    fig5_sched_final,<workload>,<scheduler>,<mean_best>,<std_best>
"""
from __future__ import annotations

import argparse
import zlib

import numpy as np

from benchmarks.workloads import (
    MEASURED_WORKLOADS,
    measured_make_step,
    surrogate_objective,
)
from repro.core import MultiFidelityConfig, SearchSpace, Tuner, TunerConfig
from repro.tuning.objective import Evaluator

ALGOS = ("bo", "ga", "nms")


class FidelitySurrogate(Evaluator):
    """The analytic surrogate made fidelity- and fork-capable so every
    scheduler runs its real code path: low fidelity adds a deterministic
    point-dependent bias that shrinks toward zero at full fidelity, and
    the checkpoint-fork blob carries a step counter (PBT lineages
    exercise resume without changing the measured value)."""

    supports_fidelity = True
    supports_fork = True

    def __init__(self, fn):
        self.fn = fn

    def __call__(self, point, fidelity=None, resume_state=None):
        f = 1.0 if fidelity is None else float(fidelity)
        v = float(self.fn(point))
        digest = zlib.crc32(repr(sorted(point.items())).encode())
        wiggle = (digest % 9 - 4) / 40.0
        steps = (resume_state or {}).get("steps", 0)
        return v * (1.0 + (1.0 - f) * wiggle), {
            "fork_state": {"steps": steps + 1}}


def run_schedulers(schedulers, budget: int = 50, seeds: int = 3,
                   parallelism: int = 1, emit=print):
    """ASHA vs HyperBand vs PBT best-so-far on the surrogate substrate."""
    summary = {}
    for w in MEASURED_WORKLOADS:
        space = SearchSpace.from_dicts(w["space"])
        for kind in schedulers:
            finals = []
            for seed in range(seeds):
                obj = FidelitySurrogate(surrogate_objective(w))
                t = Tuner(obj, space,
                          TunerConfig(algorithm="random", budget=budget,
                                      seed=seed, verbose=False,
                                      parallelism=parallelism,
                                      multi_fidelity=MultiFidelityConfig(
                                          enabled=True, scheduler=kind,
                                          min_fidelity=1 / 9, eta=3)))
                h = t.run()
                t.close()
                for it, best in enumerate(h.best_curve()):
                    emit(f"fig5_sched,{w['name']},{kind},{seed},{it},"
                         f"{best:.4f}")
                finals.append(h.best().value)
            summary[(w["name"], kind)] = (float(np.mean(finals)),
                                          float(np.std(finals)))
            emit(f"fig5_sched_final,{w['name']},{kind},"
                 f"{np.mean(finals):.4f},{np.std(finals):.4f}")
    for w in MEASURED_WORKLOADS:
        scores = {k: summary[(w["name"], k)][0] for k in schedulers}
        winner = max(scores, key=scores.get)
        emit(f"fig5_sched_winner,{w['name']},{winner}")
    return summary


def run(measured: bool = False, budget: int = 50, seeds: int = 3,
        parallelism: int = 1, emit=print):
    summary = {}
    for w in MEASURED_WORKLOADS:
        space = SearchSpace.from_dicts(w["space"])
        for algo in ALGOS:
            finals = []
            for seed in range(seeds):
                if measured:
                    from repro.tuning.evaluator import WallClockEvaluator

                    obj = WallClockEvaluator(measured_make_step(w), iters=2)
                else:
                    obj = surrogate_objective(w)
                t = Tuner(obj, space,
                          TunerConfig(algorithm=algo, budget=budget,
                                      seed=seed, verbose=False,
                                      parallelism=parallelism))
                h = t.run()
                t.close()
                for it, best in enumerate(h.best_curve()):
                    emit(f"fig5,{w['name']},{algo},{seed},{it},{best:.4f}")
                finals.append(h.best().value)
            summary[(w["name"], algo)] = (float(np.mean(finals)),
                                          float(np.std(finals)))
            emit(f"fig5_final,{w['name']},{algo},"
                 f"{np.mean(finals):.4f},{np.std(finals):.4f}")
    # who wins each workload?
    for w in MEASURED_WORKLOADS:
        scores = {a: summary[(w["name"], a)][0] for a in ALGOS}
        winner = max(scores, key=scores.get)
        emit(f"fig5_winner,{w['name']},{winner}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true")
    ap.add_argument("--budget", type=int, default=50)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--parallelism", type=int, default=1,
                    help="evaluation worker-pool width (batched ask/tell)")
    ap.add_argument("--scheduler", default=None,
                    help="comma-separated trial schedulers to compare "
                         "(asha,hyperband,pbt) instead of the search-"
                         "engine comparison")
    args = ap.parse_args(argv)
    if args.scheduler:
        kinds = [k.strip() for k in args.scheduler.split(",") if k.strip()]
        return run_schedulers(kinds, budget=args.budget, seeds=args.seeds,
                              parallelism=args.parallelism)
    run(measured=args.measured, budget=args.budget, seeds=args.seeds,
        parallelism=args.parallelism)


if __name__ == "__main__":
    main()
