"""Paper Fig. 5: tuning curves — BO vs GA vs NMS on every workload.

Default: surrogate objective, 50 iterations, 3 seeds (seconds).
``--measured``: real wall-clock measurement of each configuration on the
local device (the paper's harness; minutes).  CSV rows:

    fig5,<workload>,<algo>,<seed>,<iter>,<best_so_far>
    fig5_final,<workload>,<algo>,<mean_best>,<std_best>
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.workloads import (
    MEASURED_WORKLOADS,
    measured_make_step,
    surrogate_objective,
)
from repro.core import SearchSpace, Tuner, TunerConfig

ALGOS = ("bo", "ga", "nms")


def run(measured: bool = False, budget: int = 50, seeds: int = 3,
        parallelism: int = 1, emit=print):
    summary = {}
    for w in MEASURED_WORKLOADS:
        space = SearchSpace.from_dicts(w["space"])
        for algo in ALGOS:
            finals = []
            for seed in range(seeds):
                if measured:
                    from repro.tuning.evaluator import WallClockEvaluator

                    obj = WallClockEvaluator(measured_make_step(w), iters=2)
                else:
                    obj = surrogate_objective(w)
                t = Tuner(obj, space,
                          TunerConfig(algorithm=algo, budget=budget,
                                      seed=seed, verbose=False,
                                      parallelism=parallelism))
                h = t.run()
                t.close()
                for it, best in enumerate(h.best_curve()):
                    emit(f"fig5,{w['name']},{algo},{seed},{it},{best:.4f}")
                finals.append(h.best().value)
            summary[(w["name"], algo)] = (float(np.mean(finals)),
                                          float(np.std(finals)))
            emit(f"fig5_final,{w['name']},{algo},"
                 f"{np.mean(finals):.4f},{np.std(finals):.4f}")
    # who wins each workload?
    for w in MEASURED_WORKLOADS:
        scores = {a: summary[(w["name"], a)][0] for a in ALGOS}
        winner = max(scores, key=scores.get)
        emit(f"fig5_winner,{w['name']},{winner}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true")
    ap.add_argument("--budget", type=int, default=50)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--parallelism", type=int, default=1,
                    help="evaluation worker-pool width (batched ask/tell)")
    args = ap.parse_args(argv)
    run(measured=args.measured, budget=args.budget, seeds=args.seeds,
        parallelism=args.parallelism)


if __name__ == "__main__":
    main()
