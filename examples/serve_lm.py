"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-3b]

Runs the continuous-batching server driver on the reduced config of the
chosen architecture — same serve_step code the decode_32k/long_500k
dry-run cells lower.
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--requests", "12", "--prompt-len", "48",
                "--gen-len", "16", "--batch", "4"])


if __name__ == "__main__":
    main()
