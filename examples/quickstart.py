"""Quickstart: the paper's contribution in 40 lines.

Tunes this framework's backend parameters for a tiny dense LM's measured
training throughput with all three of the paper's gradient-free engines,
then prints the per-engine bests and exploration coverage (Table 2 style).

    PYTHONPATH=src:. python examples/quickstart.py
"""

from benchmarks.workloads import MEASURED_WORKLOADS, measured_make_step
from repro.core import SearchSpace, Tuner, TunerConfig
from repro.tuning.evaluator import WallClockEvaluator


def main():
    workload = MEASURED_WORKLOADS[0]  # dense_lm (tiny qwen2)
    space = SearchSpace.from_dicts(workload["space"])
    print(f"tuning {workload['name']}: dims={space.names} "
          f"(grid {space.grid_size()})")

    objective = WallClockEvaluator(measured_make_step(workload), iters=2)

    results = {}
    for algo in ("bo", "ga", "nms"):
        tuner = Tuner(
            objective, space,
            TunerConfig(algorithm=algo, budget=12, seed=0, verbose=True),
        )
        history = tuner.run()
        best = history.best()
        results[algo] = best
        cov = history.sampled_range_fraction()
        print(f"\n[{algo}] best {best.value:,.0f} tokens/s at {best.point}")
        print(f"[{algo}] range coverage: "
              + ", ".join(f"{k}={100*v:.0f}%" for k, v in cov.items()) + "\n")

    winner = max(results, key=lambda a: results[a].value)
    print(f"winner: {winner} ({results[winner].value:,.0f} tokens/s)")


if __name__ == "__main__":
    main()
