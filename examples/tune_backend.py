"""Tune the production backend for one assigned (arch x shape) cell with
the roofline objective — the paper's methodology pointed at the 256-chip
mesh (each evaluation lowers + compiles the cell).

    PYTHONPATH=src python examples/tune_backend.py \
        [--arch qwen3-moe-30b-a3b] [--shape train_4k] [--budget 12] \
        [--parallelism 4] [--wall-clock 600] [--loop async|batch] \
        [--memo-cache artifacts/memo_cache.json] [--cost-aware]
        [--multi-fidelity]

How it runs (completion-driven ask/tell):

* the tuner keeps ``--parallelism`` executor workers full: the engine is
  **asked** for a candidate the moment a worker frees up, and each
  result is **told** back the moment its measurement completes — in
  completion order, so one slow compile never stalls the other workers
  at a batch barrier (``--loop batch`` restores the legacy barrier loop
  for comparison);
* a crashed or OOM configuration scores ``-inf`` without killing the
  worker pool, and ``--wall-clock`` budgets by seconds instead of
  iteration count — the deadline also bounds *in-flight* compiles:
  whatever is unfinished when it passes is abandoned unrecorded (a
  wall-clock budget selects a pool backend even at ``--parallelism 1``,
  since only a pool can abandon a running compile);
* every measurement is persisted twice over: the roofline compile cache
  (``--cache``, keyed by backend config) and the tuner's own
  ``--memo-cache`` (keyed by search-space point).  Both are atomic,
  file-locked JSON stores, so re-running this script re-evaluates
  nothing and concurrent runs merge rather than clobber;
* ``--parallelism 1`` (default) is the paper-faithful sequential loop,
  bit-for-bit identical to the pre-batching harness;
* multi-host: start a measurement worker per host with
  ``python examples/tune_backend.py --serve-worker --worker-port 9123``
  (same --arch/--shape so both ends agree on the objective), then drive
  the fleet with ``--backend remote --workers hostA:9123,hostB:9123`` —
  the engine, history, and memo cache stay on the tuner host, so the
  workers need no shared filesystem, and a worker dying mid-run just
  hands its in-flight compiles to the survivors.

`python -m repro.launch.tune` is the full 50-iteration driver used for
EXPERIMENTS.md §Perf; it exposes the same knobs plus --eval-timeout and
the serial/thread/process backend switch.
"""
import argparse

from repro.launch.tune import main as tune_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--algo", default="bo")
    ap.add_argument("--parallelism", type=int, default=1)
    ap.add_argument("--wall-clock", type=float, default=None,
                    help="seconds budget; bounds in-flight compiles too")
    ap.add_argument("--loop", default="async", choices=["async", "batch"],
                    help="completion-driven scheduler (default) vs legacy "
                         "per-batch barrier")
    ap.add_argument("--memo-cache", default="artifacts/memo_cache.json",
                    help="disk-backed memo of evaluated points; a second "
                         "run of the same job re-evaluates nothing")
    ap.add_argument("--cost-aware", action="store_true",
                    help="BO: EI-per-second acquisition (prefer cheap "
                         "compiles, sharpening as --wall-clock runs out)")
    ap.add_argument("--multi-fidelity", action="store_true",
                    help="successive-halving rungs: cheap fast-analysis "
                         "screening, top-1/eta promoted to full depth "
                         "(--budget counts full-measurement equivalents)")
    ap.add_argument("--backend", default=None,
                    choices=["serial", "thread", "process", "remote"],
                    help="evaluation backend (remote farms compiles to "
                         "--workers daemons)")
    ap.add_argument("--workers", default=None,
                    help="comma-separated host:port measurement workers "
                         "(implies --backend remote)")
    ap.add_argument("--serve-worker", action="store_true",
                    help="serve this cell's objective as a measurement "
                         "worker instead of tuning (--parallelism = "
                         "concurrent-measurement slots)")
    ap.add_argument("--worker-port", type=int, default=9123,
                    help="--serve-worker: port to listen on")
    args = ap.parse_args()
    argv = [
        "--arch", args.arch, "--shape", args.shape, "--algo", args.algo,
        "--budget", str(args.budget),
        "--parallelism", str(args.parallelism),
        "--loop", args.loop,
        "--cache", "artifacts/tune_cache.json",
        "--memo-cache", args.memo_cache,
    ]
    if args.wall_clock is not None:
        argv += ["--wall-clock", str(args.wall_clock)]
    if args.cost_aware:
        argv += ["--cost-aware"]
    if args.multi_fidelity:
        argv += ["--multi-fidelity"]
    if args.backend is not None:
        argv += ["--backend", args.backend]
    if args.workers is not None:
        argv += ["--workers", args.workers]
    if args.serve_worker:
        argv += ["--serve-worker", "--worker-port", str(args.worker_port)]
    tune_main(argv)


if __name__ == "__main__":
    main()
