"""Tune the production backend for one assigned (arch x shape) cell with
the roofline objective — the paper's methodology pointed at the 256-chip
mesh (each evaluation lowers + compiles the cell).

    PYTHONPATH=src python examples/tune_backend.py \
        [--arch qwen3-moe-30b-a3b] [--shape train_4k] [--budget 12] \
        [--parallelism 4] [--wall-clock 600]

How it runs (batched ask/tell):

* the engine is **asked** for ``--parallelism`` candidate points per
  round (``engine.ask(n, history)``), the parallel executor compiles
  them concurrently (XLA releases the GIL, so the thread pool overlaps
  the ~30-90 s compiles), and the results are **told** back
  (``engine.tell(points, values)``);
* a crashed or OOM configuration scores ``-inf`` without killing the
  worker pool, and ``--wall-clock`` lets you budget by seconds instead
  of iteration count — with a small budget of real compiles, wall-clock
  budgeting is usually what you want;
* ``--parallelism 1`` (default) is the paper-faithful sequential loop.

`python -m repro.launch.tune` is the full 50-iteration driver used for
EXPERIMENTS.md §Perf; it exposes the same knobs plus --eval-timeout and
--executor-backend.
"""
import argparse

from repro.launch.tune import main as tune_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--algo", default="bo")
    ap.add_argument("--parallelism", type=int, default=1)
    ap.add_argument("--wall-clock", type=float, default=None)
    args = ap.parse_args()
    argv = [
        "--arch", args.arch, "--shape", args.shape, "--algo", args.algo,
        "--budget", str(args.budget),
        "--parallelism", str(args.parallelism),
        "--cache", "artifacts/tune_cache.json",
    ]
    if args.wall_clock is not None:
        argv += ["--wall-clock", str(args.wall_clock)]
    tune_main(argv)


if __name__ == "__main__":
    main()
