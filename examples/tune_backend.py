"""Tune the production backend for one assigned (arch x shape) cell with
the roofline objective — the paper's methodology pointed at the 256-chip
mesh (each evaluation lowers + compiles the cell).

    PYTHONPATH=src python examples/tune_backend.py \
        [--arch qwen3-moe-30b-a3b] [--shape train_4k] [--budget 12]

NOTE: every evaluation is a real XLA compile (~30-90 s on this CPU), so
the default budget is small; `python -m repro.launch.tune` is the full
50-iteration driver used for EXPERIMENTS.md §Perf.
"""
import argparse

from repro.launch.tune import main as tune_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--algo", default="bo")
    args = ap.parse_args()
    tune_main([
        "--arch", args.arch, "--shape", args.shape, "--algo", args.algo,
        "--budget", str(args.budget),
        "--cache", "artifacts/tune_cache.json",
    ])


if __name__ == "__main__":
    main()
