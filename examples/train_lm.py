"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with checkpointing and a simulated worker failure + recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--small]

``--small`` uses the tiny reduced config (CI-friendly, ~1 minute); the
default builds a ~100M-parameter qwen2-family model (slow on CPU but real:
same code path as the production launcher).
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    args = ap.parse_args()

    argv = [
        "--arch", "qwen2-0.5b", "--reduced",
        "--steps", str(args.steps),
        "--checkpoint-dir", "/tmp/repro_train_lm_ckpt",
        "--checkpoint-every", "50",
        "--inject-failure", str(args.steps // 2),
        "--lr", "1e-3",
    ]
    if args.small:
        argv += ["--batch", "8", "--seq", "128"]
    else:
        # ~100M params: widen the reduced config (24L family structure kept)
        argv += ["--batch", "8", "--seq", "256", "--d-model", "512",
                 "--layers", "12"]
    train_main(argv)


if __name__ == "__main__":
    main()
