"""Behavioural tests for the three paper engines + GP surrogate."""
import numpy as np
import pytest

from repro.core import (
    GaussianProcess,
    IntDim,
    CatDim,
    SearchSpace,
    Tuner,
    TunerConfig,
)

SPACE = SearchSpace([
    IntDim("a", 1, 56, 1),
    IntDim("b", 1, 56, 1),
    IntDim("c", 0, 200, 10),
    CatDim("d", (1, 2, 3, 4)),
])


def objective(p):
    a, b, c, d = p["a"], p["b"], p["c"], p["d"]
    y = 100 * np.exp(-((a - 40) / 12) ** 2) + 40 * np.exp(-((a - 10) / 6) ** 2)
    y += 5 * np.tanh(b / 20) + 10 * np.exp(-((c) / 40) ** 2) + 3 * d
    return float(y)


def run(algo, seed=0, budget=50):
    t = Tuner(objective, SPACE,
              TunerConfig(algorithm=algo, budget=budget, seed=seed,
                          verbose=False))
    return t.run()


def test_gp_fits_smooth_function():
    rng = np.random.default_rng(0)
    X = rng.random((30, 2))
    y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2
    gp = GaussianProcess().fit(X, y)
    Xs = rng.random((20, 2))
    post = gp.posterior(Xs)
    ys = np.sin(3 * Xs[:, 0]) + Xs[:, 1] ** 2
    assert np.sqrt(np.mean((post.mu - ys) ** 2)) < 0.15
    # posterior at training points must be near-interpolating
    post_tr = gp.posterior(X)
    assert np.sqrt(np.mean((post_tr.mu - y) ** 2)) < 0.05


def test_gp_uncertainty_grows_away_from_data():
    X = np.array([[0.1, 0.1], [0.2, 0.2], [0.15, 0.12]])
    y = np.array([1.0, 1.2, 1.1])
    gp = GaussianProcess().fit(X, y)
    near = gp.posterior(np.array([[0.15, 0.15]])).sigma[0]
    far = gp.posterior(np.array([[0.9, 0.9]])).sigma[0]
    assert far > near


@pytest.mark.parametrize("algo", [
    pytest.param("bo", marks=pytest.mark.slow),  # 50 GP refits on a 263k grid
    "ga", "nms", "random",
])
def test_engine_improves_over_budget(algo):
    h = run(algo, seed=1)
    curve = h.best_curve()
    assert curve[-1] > curve[4]  # learned something after init
    assert len(h) == 50


@pytest.mark.slow
def test_bo_beats_random_on_average():
    bo = np.mean([run("bo", seed=s).best().value for s in range(3)])
    rnd = np.mean([run("random", seed=s).best().value for s in range(3)])
    assert bo >= rnd - 1.0


@pytest.mark.slow
def test_bo_explores_full_ranges():
    """Paper Table 2: BO samples ~100% of every parameter's range."""
    h = run("bo", seed=0)
    fracs = h.sampled_range_fraction()
    assert all(f >= 0.8 for f in fracs.values()), fracs


def test_engines_dedup_evaluations():
    h = run("ga", seed=2)
    keys = [SPACE.key(p) for p in h.points()]
    # memoization would make repeats free, but engines should mostly avoid them
    assert len(set(keys)) >= int(0.9 * len(keys))


@pytest.mark.slow  # 30 BO iterations; the fast failure-isolation coverage
def test_tuner_handles_failing_objective():  # lives in test_executor.py
    calls = {"n": 0}

    def flaky(p):
        calls["n"] += 1
        if p["a"] < 28:
            raise RuntimeError("OOM")
        return objective(p)

    t = Tuner(flaky, SPACE, TunerConfig(algorithm="bo", budget=30, seed=0,
                                        verbose=False))
    h = t.run()
    assert len(h) == 30
    assert np.isfinite(h.best().value)
    assert any(not np.isfinite(e.value) for e in h.evals)  # failures recorded


def test_tuner_checkpoint_resume(tmp_path):
    ck = tmp_path / "tuner.json"
    t1 = Tuner(objective, SPACE,
               TunerConfig(algorithm="ga", budget=10, seed=3, verbose=False,
                           checkpoint_path=str(ck)))
    h1 = t1.run()
    # resume with a larger budget: must keep the first 10 evaluations
    t2 = Tuner(objective, SPACE,
               TunerConfig(algorithm="ga", budget=20, seed=3, verbose=False,
                           checkpoint_path=str(ck)))
    h2 = t2.run()
    assert len(h2) == 20
    assert h2.points()[:10] == h1.points()


def test_nms_simplex_progresses():
    """NMS must run its full state machine without stalling."""
    h = run("nms", seed=4, budget=40)
    assert len(h) == 40
    assert np.isfinite(h.best().value)


def test_exhaustive_enumerates_small_grid():
    space = SearchSpace([IntDim("a", 0, 3, 1), CatDim("b", ("x", "y"))])
    t = Tuner(lambda p: float(p["a"]), space,
              TunerConfig(algorithm="exhaustive", budget=8, verbose=False))
    h = t.run()
    assert len({space.key(p) for p in h.points()}) == 8
    assert h.best().point["a"] == 3
