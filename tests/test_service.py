"""The tuning service (tuning-as-a-service daemon): protocol-v2
negotiation, JobSpec/TunerConfig submission validation over the wire,
multi-job fair-share scheduling, cancel, crash-restart recovery with
zero double-recorded and zero lost completed results, and the v1-worker
compatibility + worker startup-error paths of the shared fleet.
"""
import json
import socket
import time
from types import SimpleNamespace

import pytest

from repro.core import IntDim, SearchSpace, TunerConfig
from repro.launch.service import ServiceClient, TuningService
from repro.launch.worker import resolve_objective
from repro.tuning import protocol as proto
from repro.tuning.objective import CountingEvaluator
from repro.tuning.protocol import (PROTOCOL_V1, PROTOCOL_V2, JobSpec, hello,
                                   negotiate, recv_msg, send_msg)
from repro.tuning.remote import RemoteWorkerPool, WorkerServer

SPACE = [{"type": "int", "name": "a", "min": 0, "max": 7},
         {"type": "int", "name": "b", "min": 0, "max": 3}]


def value_of(p) -> float:
    return float(p["a"] * 10 + p["b"])


def slow_value_of(p) -> float:
    time.sleep(0.02)
    return value_of(p)


def job_config(**over) -> dict:
    cfg = TunerConfig(algorithm="exhaustive", budget=8, verbose=False)
    d = cfg.to_dict()
    d.update(over)
    return d


@pytest.fixture
def service(tmp_path):
    svc = TuningService(tmp_path / "state", objective=value_of,
                        parallelism=4, verbose=False).start()
    yield svc
    svc.stop()


def wait_until(pred, timeout=20.0, interval=0.02):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# protocol v2 negotiation
# ---------------------------------------------------------------------------

def test_hello_pins_protocol_floor_at_v1():
    msg = hello()
    assert msg["protocol"] == PROTOCOL_V1  # v1 servers check this exact key
    assert msg["max_protocol"] == PROTOCOL_V2


def test_negotiate_picks_min_of_ceilings():
    assert negotiate({"type": "hello", "protocol": 1}) == PROTOCOL_V1
    assert negotiate(hello()) == PROTOCOL_V2
    assert negotiate(hello(max_protocol=99)) == PROTOCOL_V2
    assert negotiate(hello(), ceiling=PROTOCOL_V1) == PROTOCOL_V1


def test_negotiate_rejects_incompatible_hellos():
    assert negotiate({"type": "hello", "protocol": 2}) is None  # floor moved
    assert negotiate({"type": "register", "protocol": 1}) is None
    assert negotiate({"type": "hello", "protocol": 1,
                      "max_protocol": "garbage"}) is None


def test_service_rejects_v1_only_clients(service):
    with socket.create_connection((service.host, service.port)) as s:
        send_msg(s, {"type": "hello", "protocol": 1})  # no max_protocol
        reply = recv_msg(s)
    assert reply["type"] == "error"
    assert "protocol" in reply["error"]


# ---------------------------------------------------------------------------
# JobSpec validation
# ---------------------------------------------------------------------------

def test_jobspec_roundtrip_and_unknown_keys():
    spec = JobSpec(space=SPACE, config=job_config(), name="n")
    assert JobSpec.from_dict(spec.to_dict()).space == SPACE
    with pytest.raises(ValueError, match="unknown"):
        JobSpec.from_dict({"space": SPACE, "budget": 5})
    with pytest.raises(ValueError):
        JobSpec.from_dict({"space": []})


# ---------------------------------------------------------------------------
# submit / status / list / cancel over the wire
# ---------------------------------------------------------------------------

def test_submit_runs_to_done_with_live_status(service):
    with ServiceClient(service.address) as client:
        job_id = client.submit(JobSpec(space=SPACE, config=job_config(),
                                       name="smoke"))
        assert job_id == "job-0001"
        st = client.wait(job_id, timeout=30)
    assert st["state"] == "done"
    assert st["n_evals"] == 8
    assert st["error"] is None
    assert st["best"]["value"] == max(
        value_of(e["point"]) for e in json.loads(
            (service.jobs_dir / job_id / "history.json").read_text()))
    # best-so-far curve is monotone and one entry per eval
    curve = st["best_curve"]
    assert len(curve) == 8 and curve == sorted(curve)


def test_list_jobs_and_errors_over_the_wire(service):
    with ServiceClient(service.address) as client:
        job_id = client.submit(JobSpec(space=SPACE, config=job_config()))
        client.wait(job_id, timeout=30)
        jobs = client.list_jobs()
        assert [j["job_id"] for j in jobs] == [job_id]
        assert jobs[0]["state"] == "done"
        with pytest.raises(RuntimeError, match="no such job"):
            client.status("job-9999")
        with pytest.raises(RuntimeError, match="no such job"):
            client.cancel("job-9999")


def test_submit_rejects_unknown_config_keys_naming_them(service):
    with ServiceClient(service.address) as client:
        with pytest.raises(RuntimeError) as e:
            client.submit(JobSpec(space=SPACE,
                                  config={"algorithm": "exhaustive",
                                          "parallelism": 2}))
    # the v1->v2 migration hint names the key's new home
    assert "parallelism" in str(e.value)
    assert "executor.parallelism" in str(e.value)


def test_submit_rejects_bad_space(service):
    with ServiceClient(service.address) as client:
        with pytest.raises(RuntimeError):
            client.submit(JobSpec(space=[{"type": "warp", "name": "x"}],
                                  config=job_config()))


def test_cancel_stops_a_running_job(tmp_path):
    svc = TuningService(tmp_path / "state", objective=slow_value_of,
                        parallelism=2, verbose=False).start()
    try:
        with ServiceClient(svc.address) as client:
            job_id = client.submit(JobSpec(
                space=SPACE, config=job_config(budget=1000)))
            assert wait_until(
                lambda: client.status(job_id).get("n_evals", 0) >= 2)
            reply = client.cancel(job_id)
            assert reply["was_running"] is True
            st = client.wait(job_id, timeout=30)
        assert st["state"] == "cancelled"
        assert 0 < st["n_evals"] < 1000
    finally:
        svc.stop()


def test_two_concurrent_jobs_share_the_fleet_and_finish(tmp_path):
    svc = TuningService(tmp_path / "state", objective=slow_value_of,
                        parallelism=4, verbose=False).start()
    try:
        with ServiceClient(svc.address) as client:
            ids = [client.submit(JobSpec(space=SPACE,
                                         config=job_config(budget=12),
                                         name=f"j{i}"))
                   for i in range(2)]
            sts = [client.wait(j, timeout=60) for j in ids]
        for st in sts:
            assert st["state"] == "done"
            assert st["n_evals"] == 12
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# fair-share slot governor
# ---------------------------------------------------------------------------

def _stub_job(job_id):
    return SimpleNamespace(job_id=job_id, state="running",
                           tuner=SimpleNamespace(
                               executor=SimpleNamespace(slot_cap=None),
                               request_stop=lambda: None),
                           thread=None)


def test_rebalance_splits_slots_with_min_one(tmp_path):
    svc = TuningService(tmp_path / "state", objective=value_of,
                        parallelism=5, verbose=False)
    try:
        jobs = [_stub_job(f"job-{i:04d}") for i in range(1, 4)]
        svc._jobs = {j.job_id: j for j in jobs}
        svc._rebalance()
        caps = [j.tuner.executor.slot_cap for j in jobs]
        assert sum(caps) == 5
        assert max(caps) - min(caps) <= 1  # 5 slots / 3 jobs -> 2,2,1
        # oversubscribed: every runnable job still gets one slot
        svc._jobs = {j.job_id: j
                     for j in [_stub_job(f"job-{i:04d}") for i in range(1, 9)]}
        svc._rebalance()
        assert all(j.tuner.executor.slot_cap == 1
                   for j in svc._jobs.values())
    finally:
        svc.stop()


def test_rebalance_rotates_the_remainder(tmp_path):
    svc = TuningService(tmp_path / "state", objective=value_of,
                        parallelism=5, verbose=False)
    try:
        jobs = [_stub_job(f"job-{i:04d}") for i in range(1, 3)]
        svc._jobs = {j.job_id: j for j in jobs}
        svc._rebalance()
        first = [j.tuner.executor.slot_cap for j in jobs]
        svc._rebalance(rotate=True)
        second = [j.tuner.executor.slot_cap for j in jobs]
        assert sorted(first) == sorted(second) == [2, 3]
        assert first != second  # the bonus slot moved to the other job
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# crash-restart recovery
# ---------------------------------------------------------------------------

def test_restart_resumes_unfinished_jobs_exactly_once(tmp_path):
    """Daemon dies mid-run; a new daemon on the same state dir resumes
    the job, loses only in-flight work, re-measures nothing that was
    checkpointed, and double-records nothing."""
    state = tmp_path / "state"
    budget = 30
    svc1 = TuningService(state, objective=slow_value_of, parallelism=2,
                         verbose=False).start()
    with ServiceClient(svc1.address) as client:
        job_id = client.submit(JobSpec(space=SPACE,
                                       config=job_config(budget=budget)))
        assert wait_until(
            lambda: client.status(job_id).get("n_evals", 0) >= 4)
    svc1.stop()  # jobs stop at next completion; doc stays non-terminal

    hist_path = state / "jobs" / job_id / "history.json"
    before = json.loads(hist_path.read_text())
    assert 0 < len(before) < budget  # genuinely mid-run

    counting = CountingEvaluator(value_of)
    svc2 = TuningService(state, objective=counting, parallelism=2,
                         verbose=False).start()
    try:
        with ServiceClient(svc2.address) as client:
            st = client.wait(job_id, timeout=60)
        assert st["state"] == "done"
        assert st["n_evals"] == budget
        after = json.loads(hist_path.read_text())
        # zero lost completed results: the checkpointed prefix survived
        assert after[:len(before)] == before
        # zero double-recorded: every point appears exactly once
        keys = [tuple(sorted(e["point"].items())) for e in after]
        assert len(keys) == len(set(keys))
        # nothing checkpointed was measured again
        assert counting.calls == budget - len(before)
    finally:
        svc2.stop()


def test_restart_registers_finished_jobs_without_relaunch(tmp_path):
    state = tmp_path / "state"
    svc1 = TuningService(state, objective=value_of, parallelism=2,
                         verbose=False).start()
    with ServiceClient(svc1.address) as client:
        job_id = client.submit(JobSpec(space=SPACE, config=job_config()))
        client.wait(job_id, timeout=30)
    svc1.stop()

    svc2 = TuningService(state, objective=value_of, parallelism=2,
                         verbose=False).start()
    try:
        with ServiceClient(svc2.address) as client:
            st = client.status(job_id)
            assert st["state"] == "done"
            assert st["n_evals"] == 8  # recomputed from history on disk
            # fresh submissions do not collide with recovered ids
            new_id = client.submit(JobSpec(space=SPACE, config=job_config()))
            assert new_id != job_id
            client.wait(new_id, timeout=30)
    finally:
        svc2.stop()


# ---------------------------------------------------------------------------
# per-job objectives (local mode)
# ---------------------------------------------------------------------------

def test_daemon_without_objective_requires_job_spec(tmp_path, monkeypatch):
    (tmp_path / "objmod.py").write_text(
        "def make():\n"
        "    return lambda p: float(p['a'] + p['b'])\n")
    monkeypatch.syspath_prepend(str(tmp_path))
    svc = TuningService(tmp_path / "state", parallelism=2,
                        verbose=False).start()
    try:
        with ServiceClient(svc.address) as client:
            with pytest.raises(RuntimeError, match="objective"):
                client.submit(JobSpec(space=SPACE, config=job_config()))
            with pytest.raises(RuntimeError, match="no attribute"):
                client.submit(JobSpec(space=SPACE, config=job_config(),
                                      objective="objmod:nope()"))
            job_id = client.submit(JobSpec(space=SPACE, config=job_config(),
                                           objective="objmod:make()"))
            st = client.wait(job_id, timeout=30)
        assert st["state"] == "done"
        # the job ran the per-job objective (a + b), not the default
        hist = json.loads((svc.jobs_dir / job_id / "history.json")
                          .read_text())
        assert st["best"]["value"] == max(
            e["point"]["a"] + e["point"]["b"] for e in hist)
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# v1 worker compatibility + worker startup-error mode
# ---------------------------------------------------------------------------

def test_v1_worker_still_registers_with_v2_pool():
    server = WorkerServer(value_of, slots=2,
                          protocol_ceiling=PROTOCOL_V1).start()
    try:
        pool = RemoteWorkerPool([f"{server.host}:{server.port}"])
        try:
            health = pool.fleet_health()
            assert health[0]["protocol"] == PROTOCOL_V1
            assert health[0]["slots"] == 2
        finally:
            pool.shutdown()
    finally:
        server.stop()


def test_v2_worker_negotiates_v2():
    server = WorkerServer(value_of, slots=1).start()
    try:
        pool = RemoteWorkerPool([f"{server.host}:{server.port}"])
        try:
            assert pool.fleet_health()[0]["protocol"] == PROTOCOL_V2
        finally:
            pool.shutdown()
    finally:
        server.stop()


def test_worker_startup_error_reaches_the_tuner():
    server = WorkerServer(None, startup_error="objective spec 'x:y' "
                          "failed: No module named 'x'").start()
    try:
        with pytest.raises(ConnectionError) as e:
            RemoteWorkerPool([f"{server.host}:{server.port}"])
        assert "failed at startup" in str(e.value)
        assert "No module named 'x'" in str(e.value)
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# --objective spec resolution error messages
# ---------------------------------------------------------------------------

def test_resolve_objective_error_messages():
    with pytest.raises(ValueError, match="not module:attr"):
        resolve_objective("no_colon_here")
    with pytest.raises(ValueError, match="cannot import module"):
        resolve_objective("definitely_not_a_module:thing")
    with pytest.raises(ValueError, match="no attribute"):
        resolve_objective("math:not_a_real_attr")
    with pytest.raises(ValueError, match="not a plain attribute"):
        resolve_objective("math:sqrt(4)")  # args are not supported
    with pytest.raises(ValueError, match="raised"):
        resolve_objective("math:sqrt()")  # factory raises (missing arg)


# ---------------------------------------------------------------------------
# TunerConfig v2 schema
# ---------------------------------------------------------------------------

def test_tunerconfig_v2_roundtrip_and_legacy_delegates():
    cfg = TunerConfig(algorithm="ga", budget=7, parallelism=3, mf_eta=2.0)
    assert cfg.executor.parallelism == 3  # flat spelling -> nested home
    assert cfg.multi_fidelity.eta == 2.0
    cfg.parallelism = 5
    assert cfg.executor.parallelism == 5

    again = TunerConfig.from_dict(cfg.to_dict())
    assert again.to_dict() == cfg.to_dict()

    with pytest.raises(ValueError) as e:
        TunerConfig.from_dict({"budget": 5, "parallelism": 2})
    assert "executor.parallelism" in str(e.value)
    with pytest.raises(ValueError, match="unknown"):
        TunerConfig.from_dict({"executor": {"warp_factor": 9}})


def test_multi_fidelity_config_bool_semantics():
    assert not TunerConfig(multi_fidelity=False).multi_fidelity
    assert TunerConfig(multi_fidelity=True).multi_fidelity
    cfg = TunerConfig.from_dict(
        {"multi_fidelity": {"enabled": False, "eta": 2.0}})
    assert not cfg.multi_fidelity  # truthiness means "is it on"
    assert cfg.multi_fidelity.eta == 2.0  # knobs survive while disabled


def test_space_to_dicts_roundtrip():
    space = SearchSpace.from_dicts(SPACE + [
        {"type": "cat", "name": "c", "choices": [1, "x"]}])
    assert SearchSpace.from_dicts(space.to_dicts()).to_dicts() \
        == space.to_dicts()
    assert space.to_dicts()[0] == {"type": "int", "name": "a",
                                   "min": 0, "max": 7, "step": 1}


def test_protocol_module_is_stdlib_only():
    """Workers and thin clients import protocol.py on hosts with no jax:
    it must never pull the heavyweight stack in."""
    import pathlib
    import subprocess
    import sys

    src = str(pathlib.Path(proto.__file__).resolve().parents[2])
    out = subprocess.run(
        [sys.executable, "-c",
         "import sys; import repro.tuning.protocol; "
         "bad = [m for m in sys.modules if m.split('.')[0] in "
         "('jax', 'jaxlib', 'numpy')]; print(bad)"],
        capture_output=True, text=True, env={"PYTHONPATH": src})
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "[]"
