"""Kernel-autotuning objective: registry spaces, evaluator protocol,
sweep warm-start, and the masked-row NaN regression for the attention
kernels (interpret mode)."""

import math

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.tuning.kernel_objective import (
    HOST_KNOBS,
    KERNELS,
    KernelTuneEvaluator,
    kernel_space,
)


def test_registry_spaces_are_valid_search_spaces():
    from repro.core.space import SearchSpace

    for name, spec in KERNELS.items():
        dims = kernel_space(name)
        space = SearchSpace.from_dicts(dims)
        assert space.grid_size() >= 2, name
        # every dim name is a knob the kernel builder accepts
        assert set(space.names) <= set(spec.knobs), name


def test_kernel_space_host_knobs_are_appended():
    dims = kernel_space("rmsnorm", host_knobs=True)
    names = [d["name"] for d in dims]
    for k in HOST_KNOBS:
        assert k in names


def test_evaluator_measures_and_reports_meta():
    ev = KernelTuneEvaluator("rmsnorm", {"rows": 32, "D": 32}, iters=2)
    value, meta = ev({"block_rows": 16})
    assert math.isfinite(value) and value > 0
    assert meta["kernel"] == "rmsnorm"
    assert meta["cost_seconds"] > 0 and meta["iters"] >= 2


def test_evaluator_fidelity_contract():
    ev = KernelTuneEvaluator("gla_scan", {"B": 1, "S": 16, "H": 1,
                                          "dk": 8, "dv": 8}, iters=2)
    assert ev.supports_fidelity
    v_part, meta = ev({"chunk": 8}, fidelity=0.25)
    assert math.isfinite(v_part)
    assert meta["fidelity"] == 0.25  # partial measurements are labeled


def test_evaluator_rejects_stray_point_keys():
    ev = KernelTuneEvaluator("rmsnorm", {"rows": 16, "D": 16})
    with pytest.raises(ValueError, match="blok_rows"):
        ev({"blok_rows": 8})


def test_evaluator_rejects_host_knobs_without_subprocess():
    ev = KernelTuneEvaluator("rmsnorm", {"rows": 16, "D": 16})
    with pytest.raises(ValueError, match="allow_subprocess"):
        ev({"block_rows": 8, "host_devices": 2})


def test_unknown_kernel_is_loud():
    with pytest.raises(ValueError, match="unknown kernel"):
        KernelTuneEvaluator("nope")


def test_sweep_cold_then_warm_measures_zero(tmp_path):
    from benchmarks.kernel_sweep import lookup_latency_ms, run_sweep
    from repro.tuning.tundb import TuningDB

    path = str(tmp_path / "tundb.json")
    kernels = ["rmsnorm", "gla_scan"]
    db = TuningDB(path)
    rows, measured = run_sweep(kernels, db, budget=2, iters=2,
                               emit=lambda *a: None)
    assert measured > 0 and len(db) == 2
    for r in rows:
        assert not r["skipped"] and math.isfinite(r["value"])
    # warm re-run from a fresh instance on the same path: 0 measurements
    warm = TuningDB(path)
    rows2, measured2 = run_sweep(kernels, warm, budget=2, iters=2,
                                 emit=lambda *a: None)
    assert measured2 == 0 and all(r["skipped"] for r in rows2)
    # the stored best round-trips verbatim
    assert [r["best"] for r in rows2] == [r["best"] for r in rows]
    assert lookup_latency_ms(warm, kernels, trials=20) < 1.0


@pytest.mark.slow
def test_subprocess_measurement_with_host_knobs():
    # host knobs need a fresh process (XLA_FLAGS is read once at jax
    # import); the harness re-invokes this module with the flags set
    import math as _math

    ev = KernelTuneEvaluator("rmsnorm", {"rows": 16, "D": 16}, iters=2,
                             allow_subprocess=True)
    v, meta = ev({"block_rows": 8, "host_devices": 2, "xla_flags": ""})
    assert _math.isfinite(v) and v > 0
    assert meta["host"]["host_devices"] == 2


# ---------------------------------------------------------------------------
# masked-row NaN regression (interpret mode vs the jnp oracle)
# ---------------------------------------------------------------------------


def _qkv(B, Sq, Sk, H, K, dh, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (B, Sq, H, dh), jnp.float32),
            jax.random.normal(kk, (B, Sk, K, dh), jnp.float32),
            jax.random.normal(kv, (B, Sk, K, dh), jnp.float32))


@pytest.mark.parametrize("case", [
    # non-causal small window with Sq > Skv: trailing query rows see no key
    dict(Sq=12, Sk=4, causal=False, window=2),
    # causal window=1, block_q padding past Sq inside the tile
    dict(Sq=5, Sk=5, causal=True, window=1),
    # causal with Sq > Skv: leading rows have an empty causal range
    dict(Sq=8, Sk=4, causal=True, window=None),
])
def test_flash_attention_masked_rows_no_nan(case):
    q, k, v = _qkv(1, case["Sq"], case["Sk"], 2, 2, 8)
    out = flash_attention(q, k, v, causal=case["causal"],
                          window=case["window"], block_q=8, block_kv=8,
                          interpret=True)
    assert not jnp.isnan(out).any(), "fully-masked rows must not emit NaN"
    expect = ref.attention_ref(q, k, v, causal=case["causal"],
                               window=case["window"])
    # compare only where the oracle itself is finite (a fully-masked row
    # is undefined in the math; the kernel pins it to exact zeros)
    alive = ~jnp.isnan(expect)
    assert jnp.allclose(jnp.where(alive, out, 0.0),
                        jnp.where(alive, expect, 0.0),
                        atol=2e-5, rtol=2e-5)
    assert (out[~alive.any(-1).any(-1)] == 0).all() if (~alive).any() else True


def test_decode_attention_length_zero_rows_no_nan():
    B, H, K, dh, Smax = 3, 2, 2, 8, 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, Smax, K, dh), jnp.float32)
    v = jax.random.normal(kv, (B, Smax, K, dh), jnp.float32)
    lengths = jnp.array([0, 5, Smax], jnp.int32)  # one empty cache slot
    out = decode_attention(q, k, v, lengths, block_kv=8, interpret=True)
    assert not jnp.isnan(out).any()
    assert (out[0] == 0).all()  # length-0 row: exact zeros, not NaN
    expect = ref.decode_attention_ref(q, k, v, lengths)
    assert jnp.allclose(out[1:], expect[1:], atol=2e-5, rtol=2e-5)
