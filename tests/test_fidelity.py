"""Multi-fidelity evaluation subsystem: rung scheduler (ASHA successive
halving), fidelity-aware executor plumbing (memo keying, preemption
races), the variance-adaptive wall-clock evaluator, and the tuner's
multi-fidelity loop.

The preemption tests pin the cancellation race explicitly (satellite of
ISSUE 4): ``future.cancel()`` may return False because a worker already
started — the preemption path must handle both outcomes without losing
or double-recording a result.
"""
import json
import math
import threading
import time
import types

import pytest

from repro.core import CatDim, IntDim, SearchSpace, Tuner, TunerConfig
from repro.tuning.executor import (
    EvaluationExecutor,
    grid_key_of,
    memo_key,
    run_objective,
)
from repro.tuning.fidelity import RungScheduler
from repro.tuning.objective import CountingEvaluator, Evaluator


def make_space() -> SearchSpace:
    return SearchSpace([IntDim("inter_op", 1, 16),
                        IntDim("intra_op", 0, 60, 5),
                        CatDim("build", (1, 2, 3))])


def value_of(p):
    a, b, c = p["inter_op"], p["intra_op"], p["build"]
    return float(50.0 * pow(2.718281828, -((a - 11) / 5.0) ** 2)
                 + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)


class FidelityObjective(Evaluator):
    """Deterministic objective with an honest fidelity model: cost scales
    with fidelity, value carries a point-dependent bias shrinking as
    fidelity rises."""

    supports_fidelity = True

    def __init__(self, sleep: float = 0.0):
        self.sleep = sleep
        self.calls = []  # (key, fidelity) per real invocation

    def __call__(self, p, fidelity=None):
        f = 1.0 if fidelity is None else float(fidelity)
        self.calls.append(((p["inter_op"], p["intra_op"], p["build"]), f))
        if self.sleep:
            time.sleep(self.sleep * f)
        wiggle = ((p["inter_op"] * 13 + p["intra_op"] * 7) % 9 - 4) / 2.0
        return value_of(p) + (1.0 - f) * wiggle, {"cost_seconds": 0.01 * f}


# ---------------------------------------------------------------------------
# RungScheduler unit behavior
# ---------------------------------------------------------------------------

def test_ladder_is_geometric_in_eta():
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    assert [round(s.fidelity(r), 6) for r in range(s.n_rungs)] == [
        round(1 / 9, 6), round(1 / 3, 6), 1.0]
    assert s.base_fidelity == pytest.approx(1 / 9)
    assert s.is_top(2) and not s.is_top(1)
    # degenerate ladder: min == max -> single full-fidelity rung
    assert RungScheduler(eta=3.0, min_fidelity=1.0).n_rungs == 1


def test_promotion_needs_eta_completions_and_top_quantile():
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    p = {"x": 1}
    s.on_result(("a",), p, 10.0, 0)
    s.on_result(("b",), p, 5.0, 0)
    assert s.next_promotion() is None  # rung too small to rank
    s.on_result(("c",), p, 1.0, 0)
    point, rung = s.next_promotion()
    assert rung == 1  # best of the rung promotes first
    assert s.next_promotion() is None  # only top floor(3/3)=1 promotable
    # rung grows: floor(6/3)=2 -> the second-best becomes promotable
    for k, v in [("d", 0.5), ("e", 0.25), ("f", 0.125)]:
        s.on_result((k,), p, v, 0)
    _, rung = s.next_promotion()
    assert rung == 1
    assert s.next_promotion() is None


def test_promotion_prefers_deepest_rung_and_skips_failures():
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    p = {"x": 1}
    for k, v in [("a", 3.0), ("b", 2.0), ("c", 1.0)]:
        s.on_result((k,), p, v, 0)
    for k, v in [("a", 3.1), ("d", 2.5), ("e", 0.1)]:
        s.on_result((k,), p, v, 1)
    _, rung = s.next_promotion()
    assert rung == 2  # the rung-1 survivor outranks rung-0 promotions
    # -inf (failed) results never promote
    s2 = RungScheduler(eta=3.0, min_fidelity=0.1)
    for k in "abc":
        s2.on_result((k,), p, -math.inf, 0)
    assert s2.next_promotion() is None


def test_dominated_tracks_rising_cutoff_and_preempt_returns_key():
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    p = {"x": 1}
    for k, v in [("a", 10.0), ("b", 9.0), ("c", 1.0)]:
        s.on_result((k,), p, v, 0)
    point, rung = s.next_promotion()  # "a" promotes at value 10
    assert not s.dominated(("a",), rung)
    # six better results land: cutoff rises past 10 -> "a" is outclassed
    for k, v in [("d", 20.0), ("e", 19.0), ("f", 18.0),
                 ("g", 17.0), ("h", 16.0), ("i", 15.0)]:
        s.on_result((k,), p, v, 0)
    assert s.dominated(("a",), rung)
    # a cancelled preemption returns the key to the unpromoted pool and
    # counts on the target rung (whose start it cancels), so per-rung
    # stats reconcile: started = completed + preempted + in-flight
    s.on_preempted(("a",), rung)
    assert s.rungs[rung].n_preempted == 1
    assert ("a",) not in s.rungs[0].promoted
    # bottom-rung submissions carry no prior value: never dominated
    assert not s.dominated(("z",), 0)


# ---------------------------------------------------------------------------
# executor: fidelity plumbing
# ---------------------------------------------------------------------------

def test_run_objective_forwards_fidelity_only_when_supported():
    fid_obj = FidelityObjective()
    v, _s, meta = run_objective(fid_obj, {"inter_op": 1, "intra_op": 0,
                                          "build": 1}, 0.25)
    assert meta["fidelity"] == 0.25 and fid_obj.calls[0][1] == 0.25
    # plain callables are silently upgraded to a full measurement
    v2, _s, meta2 = run_objective(
        CountingEvaluator(lambda p: 7.0).inner, {"x": 1}, 0.25)
    assert v2 == 7.0 and meta2["fidelity"] == 1.0
    # full-fidelity calls keep the historical meta exactly (golden traces)
    _v, _s, meta3 = run_objective(fid_obj, {"inter_op": 1, "intra_op": 0,
                                            "build": 1}, None)
    assert "fidelity" not in meta3 or meta3["fidelity"] == 1.0


def test_memo_key_separates_fidelities_and_roundtrips_grid_key():
    gk = (1, 0, "x")
    assert memo_key(gk, None) == gk == memo_key(gk, 1.0)
    low = memo_key(gk, 1 / 3)
    assert low != gk and grid_key_of(low) == gk and grid_key_of(gk) == gk
    assert memo_key(gk, 1 / 3) == low  # stable across calls


def test_partial_results_never_served_for_full_requests(tmp_path):
    space = make_space()
    obj = FidelityObjective()
    memo = str(tmp_path / "memo.json")
    ex = EvaluationExecutor(obj, space, parallelism=1, cache_path=memo)
    p = {"inter_op": 11, "intra_op": 60, "build": 3}
    low = ex.next_completed(ex.submit([p], fidelity=1 / 9, rung=0)).result()
    full = ex.next_completed(ex.submit([p])).result()
    assert not full.meta.get("memoized")  # the cheap result was not reused
    assert full.value == pytest.approx(value_of(p))
    assert low.value != pytest.approx(full.value)  # bias is real
    # same-fidelity repeat IS a memo hit
    again = ex.next_completed(ex.submit([p], fidelity=1 / 9, rung=0)).result()
    assert again.meta.get("memoized")
    ex.close()
    assert len(obj.calls) == 2
    # the disk store reloads both entries under their own fidelity keys
    ex2 = EvaluationExecutor(FidelityObjective(), space, parallelism=1,
                             cache_path=memo)
    assert ex2.next_completed(
        ex2.submit([p], fidelity=1 / 9)).result().meta.get("memoized")
    assert ex2.next_completed(ex2.submit([p])).result().meta.get("memoized")
    ex2.close()


# ---------------------------------------------------------------------------
# executor: the preemption cancellation race (both outcomes)
# ---------------------------------------------------------------------------

def test_preempt_cancels_queued_eval_without_poisoning():
    """future.cancel() True: the task never ran — nothing recorded,
    nothing cached, and a later submit measures it for real."""
    space = make_space()
    release = threading.Event()
    calls = []

    class Blocking(Evaluator):
        supports_fidelity = True

        def __call__(self, p, fidelity=None):
            calls.append(p["inter_op"])
            release.wait(5)
            return float(p["inter_op"]), {}

    ex = EvaluationExecutor(Blocking(), space, parallelism=1,
                            backend="thread")
    pa = {"inter_op": 1, "intra_op": 0, "build": 1}
    pb = {"inter_op": 2, "intra_op": 0, "build": 1}
    (pend_a,) = ex.submit([pa], fidelity=1 / 3, rung=1)
    (pend_b,) = ex.submit([pb], fidelity=1 / 3, rung=1)  # queued behind a
    assert ex.preempt(pend_b) == "cancelled"
    assert pend_b.done() and pend_b.result().meta.get("preempted")
    release.set()
    done = ex.next_completed([pend_a])
    assert done is pend_a and done.result().value == 1.0
    # b never ran, was not cached, and can be measured later
    assert calls == [1]
    (pend_b2,) = ex.submit([pb], fidelity=1 / 3, rung=1)
    r = ex.next_completed([pend_b2]).result()
    assert r.value == 2.0 and not r.meta.get("memoized")
    assert calls == [1, 2]
    ex.close()


def test_preempt_of_started_eval_records_exactly_once():
    """future.cancel() False: a worker already started — the measurement
    finishes and is recorded exactly once, not lost, not duplicated."""
    space = make_space()
    started = threading.Event()
    release = threading.Event()

    class Signalling(Evaluator):
        supports_fidelity = True

        def __call__(self, p, fidelity=None):
            started.set()
            release.wait(5)
            return 42.0, {}

    ex = EvaluationExecutor(Signalling(), space, parallelism=1,
                            backend="thread")
    (pend,) = ex.submit([{"inter_op": 3, "intra_op": 0, "build": 1}],
                        fidelity=1 / 3, rung=1)
    assert started.wait(5), "worker never started"
    assert ex.preempt(pend) == "running"
    assert pend.preempted and not pend.done()
    release.set()
    done = ex.next_completed([pend])
    assert done is pend
    assert done.result().value == 42.0
    assert not done.result().meta.get("preempted")
    # the completed result is banked in the memo (it was paid for)
    again = ex.submit([{"inter_op": 3, "intra_op": 0, "build": 1}],
                      fidelity=1 / 3, rung=1)[0]
    assert again.done() and again.result().meta.get("memoized")
    ex.close()


def test_preempt_of_shared_future_resolves_alias_as_preempted():
    """A pending can share a running measurement with a duplicate submit
    (the stale-alias path).  Preempting one pending cancels the shared
    future; the sibling must resolve as a preempted placeholder through
    next_completed — never raise CancelledError, never record a value."""
    space = make_space()
    release = threading.Event()
    calls = []

    class Blocking(Evaluator):
        supports_fidelity = True

        def __call__(self, p, fidelity=None):
            calls.append(p["inter_op"])
            release.wait(5)
            return float(p["inter_op"]), {}

    ex = EvaluationExecutor(Blocking(), space, parallelism=1,
                            backend="thread")
    pa = {"inter_op": 1, "intra_op": 0, "build": 1}
    pb = {"inter_op": 2, "intra_op": 0, "build": 1}
    (pend_a,) = ex.submit([pa], fidelity=1 / 3)   # worker blocks on this
    (pend_b1,) = ex.submit([pb], fidelity=1 / 3)  # queued
    (pend_b2,) = ex.submit([pb], fidelity=1 / 3)  # aliases b1's future
    assert pend_b2.future is pend_b1.future
    assert ex.preempt(pend_b1) == "cancelled"
    done = ex.next_completed([pend_b2])  # must not raise CancelledError
    assert done is pend_b2
    assert done.result().meta.get("preempted")
    release.set()
    assert ex.next_completed([pend_a]).result().value == 1.0
    # nothing was measured for b; a fresh submit measures it for real
    (pend_b3,) = ex.submit([pb], fidelity=1 / 3)
    assert ex.next_completed([pend_b3]).result().value == 2.0
    assert calls == [1, 2]
    ex.close()


def test_store_reload_keys_by_requested_fidelity(tmp_path):
    """An evaluator may deliver a snapped fidelity in meta; the memo's
    lookup identity is the *requested* fidelity, so a reloaded store must
    key entries off the persisted key's fidelity tag, or a second
    identical run would re-measure every partial result."""
    space = make_space()

    class Snapping(Evaluator):
        supports_fidelity = True

        def __init__(self):
            self.calls = 0

        def __call__(self, p, fidelity=None):
            self.calls += 1
            # delivers a coarser fidelity than requested
            return 5.0, {"fidelity": 0.5}

    memo = str(tmp_path / "memo.json")
    p = {"inter_op": 9, "intra_op": 0, "build": 1}
    obj1 = Snapping()
    ex1 = EvaluationExecutor(obj1, space, parallelism=1, cache_path=memo)
    ex1.next_completed(ex1.submit([p], fidelity=1 / 9))
    ex1.close()
    assert obj1.calls == 1
    obj2 = Snapping()
    ex2 = EvaluationExecutor(obj2, space, parallelism=1, cache_path=memo)
    r = ex2.next_completed(ex2.submit([p], fidelity=1 / 9)).result()
    ex2.close()
    assert r.meta.get("memoized") and obj2.calls == 0


def test_preempt_of_completed_eval_is_noop():
    space = make_space()
    ex = EvaluationExecutor(FidelityObjective(), space, parallelism=1)
    (pend,) = ex.submit([{"inter_op": 4, "intra_op": 0, "build": 1}],
                        fidelity=1 / 9, rung=0)
    assert pend.done()  # serial backend resolves at submit
    assert ex.preempt(pend) == "done"
    assert not pend.result().meta.get("preempted")
    ex.close()


# ---------------------------------------------------------------------------
# tuner: the multi-fidelity loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["bo", "ga", "nms", "random"])
def test_multi_fidelity_loop_spends_budget_across_rungs(algo):
    obj = FidelityObjective(sleep=0.002)
    t = Tuner(obj, make_space(),
              TunerConfig(algorithm=algo, budget=8, seed=0, verbose=False,
                          parallelism=4, multi_fidelity=True))
    h = t.run()
    sched = t.rung_scheduler
    t.close()
    assert h.n_pending() == 0
    fids = sorted(set(round(e.fidelity, 6) for e in h.evals))
    assert len(fids) >= 2, f"no rung mixing: {fids}"
    assert any(e.fidelity >= 1.0 for e in h.evals), "nothing reached top rung"
    spend = sum(e.fidelity for e in h.evals)
    assert spend >= 8  # logical budget spent (drain may add a little)
    # exactly-once accounting: every real call is one history row
    measured = [e for e in h.evals if not e.meta.get("memoized")]
    assert len(obj.calls) == len(measured)
    keyed = [(t_key, round(f, 9)) for t_key, f in obj.calls]
    assert len(keyed) == len(set(keyed)), "a (point, fidelity) ran twice"
    # scheduler accounting matches history
    assert sum(r["completed"] for r in sched.stats()) == len(h.evals)


def test_multi_fidelity_full_results_match_objective_exactly():
    obj = FidelityObjective()
    t = Tuner(obj, make_space(),
              TunerConfig(algorithm="random", budget=6, seed=1, verbose=False,
                          parallelism=2, multi_fidelity=True))
    h = t.run()
    t.close()
    for e in h.evals:
        if e.fidelity >= 1.0:
            assert e.value == pytest.approx(value_of(e.point))
    best = h.best(full_fidelity_only=True)
    assert best.fidelity == 1.0


def test_multi_fidelity_degenerates_for_plain_callables():
    """An objective without fidelity support cannot cheapen a measurement:
    rungs would all cost the same and promotion would just re-measure
    points — the loop must fall back to the plain async loop, with every
    measurement charged and recorded as full fidelity."""
    calls = []

    def obj(p):
        calls.append(1)
        return value_of(p)

    t = Tuner(obj, make_space(),
              TunerConfig(algorithm="random", budget=4, seed=0, verbose=False,
                          parallelism=1, multi_fidelity=True))
    h = t.run()
    t.close()
    assert t.rung_scheduler is None  # no rung ladder was built
    assert all(e.fidelity == 1.0 for e in h.evals)
    assert len(calls) == 4  # exactly budget full measurements, not ~9x
    assert math.isfinite(h.best(full_fidelity_only=True).value)


def test_executor_normalizes_fidelity_for_plain_callables():
    """Direct submit() callers get the same protection: a partial-fidelity
    request an evaluator cannot serve is keyed (and run) as the full
    measurement it delivers, so memo entries never fragment per rung."""
    space = make_space()
    calls = []

    def obj(p):
        calls.append(1)
        return float(p["inter_op"])

    ex = EvaluationExecutor(obj, space, parallelism=1)
    p = {"inter_op": 5, "intra_op": 0, "build": 1}
    r1 = ex.next_completed(ex.submit([p], fidelity=1 / 9, rung=0)).result()
    r2 = ex.next_completed(ex.submit([p], fidelity=1 / 3, rung=1)).result()
    r3 = ex.next_completed(ex.submit([p])).result()
    ex.close()
    assert len(calls) == 1  # one measurement served all three requests
    assert r1.value == r2.value == r3.value == 5.0
    assert r2.meta.get("memoized") and r3.meta.get("memoized")


def test_multi_fidelity_requires_async_loop():
    with pytest.raises(ValueError, match="multi_fidelity"):
        Tuner(lambda p: 1.0, make_space(),
              TunerConfig(algorithm="random", loop="batch",
                          multi_fidelity=True))


def test_multi_fidelity_bo_gets_fidelity_feature():
    t = Tuner(FidelityObjective(), make_space(),
              TunerConfig(algorithm="bo", budget=4, seed=0, verbose=False,
                          multi_fidelity=True))
    assert t.engine.fidelity_feature
    t.close()
    # single-fidelity BO keeps the bit-for-bit surrogate path
    t2 = Tuner(lambda p: 1.0, make_space(),
               TunerConfig(algorithm="bo", budget=4, seed=0, verbose=False))
    assert not t2.engine.fidelity_feature
    t2.close()


def test_multi_fidelity_checkpoint_resume_continues_ladder(tmp_path):
    """Resuming a multi-fidelity run must rebuild rung state and budget
    accounting from the checkpoint: the budget is not re-spent from zero
    and replayed completions stay visible to the scheduler."""
    ck = tmp_path / "t.json"
    t1 = Tuner(FidelityObjective(), make_space(),
               TunerConfig(algorithm="random", budget=3, seed=2,
                           verbose=False, parallelism=1, multi_fidelity=True,
                           checkpoint_path=str(ck)))
    h1 = t1.run()
    t1.close()
    n1, spend1 = len(h1), sum(e.fidelity for e in h1.evals)
    assert spend1 >= 3

    t2 = Tuner(FidelityObjective(), make_space(),
               TunerConfig(algorithm="random", budget=6, seed=2,
                           verbose=False, parallelism=1, multi_fidelity=True,
                           checkpoint_path=str(ck)))
    h2 = t2.run()
    t2.close()
    assert h2.points()[:n1] == h1.points()  # replayed, not re-measured
    assert sum(e.fidelity for e in h2.evals) >= 6
    # only the remaining budget was spent (small drain slack allowed)
    assert sum(e.fidelity for e in h2.evals[n1:]) <= 6 - spend1 + 1.5
    # the scheduler saw every completion, replayed ones included
    assert sum(r["completed"] for r in t2.rung_scheduler.stats()) == len(h2)
    # replay rebuilt the promotion marks: nothing measured twice at the
    # same (point, fidelity) across the interrupt/resume boundary
    pairs = [(make_space().key(e.point), round(e.fidelity, 6))
             for e in h2.evals]
    assert len(pairs) == len(set(pairs))


def test_multi_fidelity_second_run_hits_disk_memo(tmp_path):
    memo = str(tmp_path / "memo.json")
    counting = CountingEvaluator(FidelityObjective())

    def run_once():
        t = Tuner(counting, make_space(),
                  TunerConfig(algorithm="random", budget=5, seed=3,
                              verbose=False, parallelism=1,
                              multi_fidelity=True, mf_preempt=False,
                              memo_cache_path=memo))
        h = t.run()
        t.close()
        return h

    run_once()
    first = counting.calls
    assert first > 0
    run_once()
    assert counting.calls == first, "second identical run re-measured"


def test_history_persists_fidelity(tmp_path):
    from repro.core import History
    space = make_space()
    h = History(space)
    p = {"inter_op": 1, "intra_op": 0, "build": 1}
    h.add(p, 1.0, 0.1, {"m": 1}, fidelity=1 / 3)
    h.add(p, 2.0, 0.3, {}, fidelity=1.0)
    path = tmp_path / "h.json"
    h.save(path)
    loaded = History.load(path, space)
    assert [e.fidelity for e in loaded.evals] == [pytest.approx(1 / 3), 1.0]
    assert loaded.best().value == 2.0
    assert list(loaded.fidelities()) == [pytest.approx(1 / 3), 1.0]
    # legacy records without a fidelity field load as full measurements
    recs = json.loads(path.read_text())
    for r in recs:
        del r["fidelity"]
    path.write_text(json.dumps(recs))
    assert [e.fidelity for e in History.load(path, space).evals] == [1.0, 1.0]


# ---------------------------------------------------------------------------
# variance-adaptive wall-clock measurement
# ---------------------------------------------------------------------------

def _make_step(point):
    import numpy as np

    def step(x):
        return x + 1

    return step, (np.zeros(4),), 4.0


def test_wallclock_adaptive_stops_early_on_stable_timing():
    from repro.tuning.evaluator import WallClockEvaluator
    ev = WallClockEvaluator(_make_step, warmup=1, rel_halfwidth=1e9,
                            min_iters=2, max_iters=12)
    v, meta = ev({"any": 1})
    assert meta["iters"] == 2  # CI target trivially met after min_iters
    assert v > 0 and meta["step_seconds"] > 0
    # an explicit full-fidelity request is byte-identical to a plain
    # call, meta keys included
    _v, meta_full = ev({"any": 1}, fidelity=1.0)
    assert "fidelity" not in meta_full
    assert sorted(meta_full) == sorted(meta)


def test_wallclock_adaptive_hits_cap_when_target_unreachable():
    from repro.tuning.evaluator import WallClockEvaluator
    ev = WallClockEvaluator(_make_step, warmup=1, rel_halfwidth=0.0,
                            min_iters=2, max_iters=7)
    _v, meta = ev({"any": 1})
    assert meta["iters"] == 7
    assert meta["ci_rel_halfwidth"] >= 0.0


def test_wallclock_fidelity_scales_iteration_cap():
    from repro.tuning.evaluator import WallClockEvaluator
    ev = WallClockEvaluator(_make_step, warmup=1, rel_halfwidth=0.0,
                            min_iters=2, max_iters=12)
    _v, meta = ev({"any": 1}, fidelity=0.25)
    assert meta["iters"] == 3  # ceil(12 * 0.25)
    assert meta["fidelity"] == 0.25


def test_wallclock_cost_is_measurement_only():
    from repro.tuning.evaluator import WallClockEvaluator
    ev = WallClockEvaluator(_make_step, warmup=3, rel_halfwidth=1e9)
    _v, meta = ev({"any": 1})
    # the timing loop is microseconds; build includes jit lowering+warmup
    # and is orders of magnitude larger — cost must exclude it
    assert meta["cost_seconds"] < meta["build_seconds"]
    assert meta["cost_seconds"] == pytest.approx(
        meta["step_seconds"] * meta["iters"], rel=1e-6)


def test_wallclock_fixed_iters_mode_unchanged():
    from repro.tuning.evaluator import WallClockEvaluator
    ev = WallClockEvaluator(_make_step, warmup=1, iters=3, adaptive=False)
    _v, meta = ev({"any": 1})
    assert meta["iters"] == 3


# ---------------------------------------------------------------------------
# roofline evaluator: shared-store re-consult on in-memory miss
# ---------------------------------------------------------------------------

def test_roofline_reconsults_store_before_recompiling(tmp_path, monkeypatch):
    import sys

    from repro.tuning.cache import JsonCacheStore
    from repro.tuning.evaluator import RooflineEvaluator
    from repro.tuning.parameters import BASELINE, config_from_point

    # any compile attempt is a test failure: the record must come from the
    # store written *after* the evaluator started
    stub = types.ModuleType("repro.launch.dryrun")

    def _no_compile(*a, **k):
        raise AssertionError("recompiled despite a store entry")

    stub.analyze_cell = _no_compile
    monkeypatch.setitem(sys.modules, "repro.launch.dryrun", stub)

    cache = str(tmp_path / "roofline.json")
    ev = RooflineEvaluator("qwen2-0.5b", "train_4k", cache_path=cache)
    assert ev._cache == {}  # store was empty at startup
    point = {"log2_dp": 1}
    rec = {"skipped": False,
           "memory": {"per_device_B": 1.0},
           "roofline": {"throughput_tok_s": 123.0}}
    # a concurrent host writes the entry after our __init__
    JsonCacheStore(cache).put(
        ev._key(config_from_point(point, BASELINE)), rec)
    value, meta = ev(point)
    assert value == 123.0
    # and the entry is now cached in memory (no second store read needed)
    assert len(ev._cache) == 1


def test_roofline_fast_fidelity_uses_distinct_cache_key(tmp_path):
    from repro.tuning.evaluator import RooflineEvaluator
    from repro.tuning.parameters import BASELINE

    ev = RooflineEvaluator("qwen2-0.5b", "train_4k",
                           cache_path=str(tmp_path / "c.json"))
    bc = BASELINE
    full_key, fast_key = ev._key(bc), ev._key(bc, fast=True)
    assert full_key != fast_key
    assert json.loads(full_key).get("analysis") is None  # legacy format kept
    assert json.loads(fast_key)["analysis"] == "fast"
