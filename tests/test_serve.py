"""Serving path: generate() coherence and KV-cache reuse."""
import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import CPU_TEST, build_model
from repro.models.params import split_params
from repro.serve.serve_step import generate

pytestmark = pytest.mark.slow  # real generate/decode loops


def test_generate_matches_teacher_forcing():
    """Greedy generation step-by-step == argmax of full forward each step."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    rt = CPU_TEST
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    B, S, G = 2, 16, 6
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache, _ = split_params(model.init_cache(B, S + G))
    gen, _ = generate(model, params, {"tokens": prompt}, rt=rt, cache=cache,
                      steps=G)
    assert gen.shape == (B, G)

    # teacher-forced reference: rerun full forward with generated prefix
    toks = prompt
    for t in range(G):
        logits, _, _ = model.apply(params, {"tokens": toks}, rt=rt)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(nxt[:, 0]),
                                      np.asarray(gen[:, t]))
        toks = jnp.concatenate([toks, nxt], axis=1)


def test_decode_state_isolated_across_batch():
    """Each sequence's cache must be independent (no cross-batch leaks)."""
    cfg = get_config("rwkv6-3b").reduced()
    model = build_model(cfg)
    rt = CPU_TEST
    params, _ = split_params(model.init(jax.random.PRNGKey(1)))
    rng = np.random.default_rng(1)
    p1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    p2 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    both = jnp.concatenate([p1, p2], axis=0)

    def gen_tokens(prompt, steps=4):
        cache, _ = split_params(model.init_cache(prompt.shape[0], 20))
        out, _ = generate(model, params, {"tokens": prompt}, rt=rt,
                          cache=cache, steps=steps)
        return np.asarray(out)

    joint = gen_tokens(both)
    np.testing.assert_array_equal(joint[0], gen_tokens(p1)[0])
    np.testing.assert_array_equal(joint[1], gen_tokens(p2)[0])


def test_whisper_generate_runs():
    cfg = get_config("whisper-base").reduced()
    model = build_model(cfg)
    rt = CPU_TEST
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    B, S = 2, 8
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "encoder_embeds": jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32),
    }
    cache, _ = split_params(model.init_cache(B, S + 4))
    gen, _ = generate(model, params, batch, rt=rt, cache=cache, steps=4)
    assert gen.shape == (B, 4)
    assert (np.asarray(gen) >= 0).all()
