"""End-to-end system behaviour: train -> checkpoint -> restore -> serve,
through the public launchers (the full paper pipeline on one box)."""
import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.models.params import split_params
from repro.models.runtime import Runtime
from repro.optim.optimizer import OptimizerConfig
from repro.serve.serve_step import generate
from repro.train.trainer import Trainer, TrainerConfig

pytestmark = pytest.mark.slow  # end-to-end train->checkpoint->serve + measured tuning


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    rt = Runtime(compute_dtype="f32")
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=2e-3, warmup_steps=5, total_steps=40),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8),
        TrainerConfig(steps=40, log_every=0, checkpoint_dir=str(tmp_path / "ck"),
                      checkpoint_every=20),
        rt=rt,
    )
    log = trainer.run()
    assert log[-1]["loss"] < log[0]["loss"]

    # restore the trained params into a fresh model and serve with them
    model = build_model(cfg)
    fresh, _ = split_params(model.init(jax.random.PRNGKey(7)))
    ck = Checkpointer(str(tmp_path / "ck"))
    restored, meta = ck.restore(None, {"params": fresh,
                                       "opt": trainer.opt_state})
    assert meta["step"] == 40
    params = restored["params"]

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    cache, _ = split_params(model.init_cache(2, 32))
    gen, _ = generate(model, params, {"tokens": prompt}, rt=rt, cache=cache,
                      steps=8)
    assert gen.shape == (2, 8)
    first = np.asarray(gen[:, 0])
    assert first.dtype == np.int32 and (first >= 0).all()


def test_tuner_end_to_end_on_system(tmp_path):
    """The paper pipeline: tune a real (measured) objective, resume it."""
    from benchmarks.workloads import MEASURED_WORKLOADS, measured_make_step
    from repro.core import SearchSpace, Tuner, TunerConfig
    from repro.tuning.evaluator import WallClockEvaluator

    w = MEASURED_WORKLOADS[4]  # ncf — cheapest measured workload
    space = SearchSpace.from_dicts(w["space"])
    obj = WallClockEvaluator(measured_make_step(w), warmup=1, iters=1)
    ck = tmp_path / "tune.json"
    t = Tuner(obj, space, TunerConfig(algorithm="bo", budget=6, seed=0,
                                      verbose=False, checkpoint_path=str(ck)))
    h1 = t.run()
    assert len(h1) == 6 and np.isfinite(h1.best().value)
    t2 = Tuner(obj, space, TunerConfig(algorithm="bo", budget=8, seed=0,
                                       verbose=False, checkpoint_path=str(ck)))
    h2 = t2.run()
    assert len(h2) == 8
    assert h2.points()[:6] == h1.points()
