"""Hypothesis property tests for the search space + history invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import CatDim, History, IntDim, SearchSpace


def space_strategy():
    int_dim = st.builds(
        lambda name, lo, span, step: IntDim(name, lo, lo + span * step, step),
        st.just(""), st.integers(0, 10), st.integers(1, 12), st.integers(1, 10),
    )
    cat_dim = st.builds(
        lambda name, n: CatDim(name, tuple(f"c{i}" for i in range(n))),
        st.just(""), st.integers(2, 6),
    )
    def _name(dims):
        return SearchSpace([
            (IntDim(f"d{i}", d.lo, d.hi, d.step) if isinstance(d, IntDim)
             else CatDim(f"d{i}", d.choices))
            for i, d in enumerate(dims)
        ])
    return st.lists(st.one_of(int_dim, cat_dim), min_size=1, max_size=5).map(_name)


@given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_encode_decode_roundtrip(space, seed):
    rng = np.random.default_rng(seed)
    for p in space.sample(rng, 5):
        assert space.validate(p)
        u = space.encode(p)
        assert np.all(u >= 0) and np.all(u <= 1)
        assert space.decode(u) == p  # grid points roundtrip exactly


@given(space=space_strategy(), data=st.data())
@settings(max_examples=60, deadline=None)
def test_decode_always_valid(space, data):
    u = np.array([data.draw(st.floats(-0.5, 1.5)) for _ in range(space.n_dims)])
    p = space.decode(u)
    assert space.validate(p)


@given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_perturb_stays_on_grid(space, seed):
    rng = np.random.default_rng(seed)
    p = space.sample(rng, 1)[0]
    for _ in range(10):
        p = space.perturb(rng, p)
        assert space.validate(p)


@given(space=space_strategy(), seed=st.integers(0, 2**32 - 1),
       n=st.integers(1, 30))
@settings(max_examples=40, deadline=None)
def test_history_invariants(space, seed, n):
    rng = np.random.default_rng(seed)
    h = History(space)
    best = -np.inf
    for i, p in enumerate(space.sample(rng, n)):
        v = float(rng.standard_normal())
        h.add(p, v)
        best = max(best, v)
        assert h.seen(p)
    assert len(h) == n
    assert h.best().value == best
    curve = h.best_curve()
    assert curve == sorted(curve)  # running best is monotone
    # sampled range fractions are in [0, 1]
    for frac in h.sampled_range_fraction().values():
        assert -1e-9 <= frac <= 1 + 1e-9


@given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_history_json_roundtrip(tmp_path_factory, space, seed):
    rng = np.random.default_rng(seed)
    h = History(space)
    for p in space.sample(rng, 7):
        h.add(p, float(rng.standard_normal()))
    path = tmp_path_factory.mktemp("hist") / "h.json"
    h.save(path)
    h2 = History.load(path, space)
    assert h2.points() == h.points()
    assert np.allclose(h2.values(), h.values())


def test_lhs_covers_strata():
    space = SearchSpace([IntDim("a", 0, 9, 1)])
    pts = space.sample_lhs(np.random.default_rng(0), 10)
    assert len({p["a"] for p in pts}) >= 8  # near-perfect stratification
