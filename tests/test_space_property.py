"""Property tests for the search space + history invariants.

Two layers exercise the same invariants:

* a seeded pure-pytest fallback that always runs (randomized spaces from
  ``numpy.random``), so the properties are covered even where
  ``hypothesis`` is not installed;
* the original hypothesis suite, kept under ``HAVE_HYPOTHESIS`` so it
  adds shrinking/edge-case power whenever the dependency is available.
"""
import numpy as np
import pytest

from repro.core import CatDim, History, IntDim, SearchSpace

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# shared invariant checks
# ---------------------------------------------------------------------------

def check_roundtrip(space, rng):
    for p in space.sample(rng, 5):
        assert space.validate(p)
        u = space.encode(p)
        assert np.all(u >= 0) and np.all(u <= 1)
        assert space.decode(u) == p  # grid points roundtrip exactly


def check_decode_always_valid(space, rng):
    u = rng.uniform(-0.5, 1.5, size=space.n_dims)
    assert space.validate(space.decode(u))


def check_perturb_stays_on_grid(space, rng):
    p = space.sample(rng, 1)[0]
    for _ in range(10):
        p = space.perturb(rng, p)
        assert space.validate(p)


def check_history_invariants(space, rng, n):
    h = History(space)
    best = -np.inf
    for p in space.sample(rng, n):
        v = float(rng.standard_normal())
        h.add(p, v)
        best = max(best, v)
        assert h.seen(p)
    assert len(h) == n
    assert h.best().value == best
    curve = h.best_curve()
    assert curve == sorted(curve)  # running best is monotone
    # sampled range fractions are in [0, 1]
    for frac in h.sampled_range_fraction().values():
        assert -1e-9 <= frac <= 1 + 1e-9


def check_history_json_roundtrip(space, rng, tmp_path):
    h = History(space)
    for p in space.sample(rng, 7):
        h.add(p, float(rng.standard_normal()))
    path = tmp_path / "h.json"
    h.save(path)
    h2 = History.load(path, space)
    assert h2.points() == h.points()
    assert np.allclose(h2.values(), h.values())


# ---------------------------------------------------------------------------
# seeded pure-pytest fallback (always runs)
# ---------------------------------------------------------------------------

def random_space(rng) -> SearchSpace:
    dims = []
    for i in range(int(rng.integers(1, 6))):
        if rng.random() < 0.5:
            lo = int(rng.integers(0, 11))
            span = int(rng.integers(1, 13))
            step = int(rng.integers(1, 11))
            dims.append(IntDim(f"d{i}", lo, lo + span * step, step))
        else:
            dims.append(CatDim(f"d{i}",
                               tuple(f"c{j}" for j in range(rng.integers(2, 7)))))
    return SearchSpace(dims)


@pytest.mark.parametrize("seed", range(20))
def test_space_invariants_seeded(seed, tmp_path):
    rng = np.random.default_rng(seed)
    for _ in range(3):
        space = random_space(rng)
        check_roundtrip(space, rng)
        check_decode_always_valid(space, rng)
        check_perturb_stays_on_grid(space, rng)
        check_history_invariants(space, rng, int(rng.integers(1, 31)))
    check_history_json_roundtrip(random_space(rng), rng, tmp_path)


def test_lhs_covers_strata():
    space = SearchSpace([IntDim("a", 0, 9, 1)])
    pts = space.sample_lhs(np.random.default_rng(0), 10)
    assert len({p["a"] for p in pts}) >= 8  # near-perfect stratification


def test_inflight_bookkeeping():
    space = SearchSpace([IntDim("a", 0, 9, 1)])
    h = History(space)
    p, q = {"a": 1}, {"a": 2}
    h.mark_inflight([p, q])
    assert h.pending(p) and h.pending(q) and h.n_pending() == 2
    assert not h.seen(p)  # in flight is not evaluated
    h.add(p, 1.0)  # completing an evaluation clears its in-flight mark
    assert h.seen(p) and not h.pending(p) and h.n_pending() == 1
    h.clear_inflight([q])
    assert h.n_pending() == 0


def test_save_excludes_inflight(tmp_path):
    """A checkpoint written mid-batch only holds completed evaluations."""
    space = SearchSpace([IntDim("a", 0, 9, 1)])
    h = History(space)
    h.add({"a": 0}, 1.0)
    h.mark_inflight([{"a": 5}])
    path = tmp_path / "h.json"
    h.save(path)
    h2 = History.load(path, space)
    assert h2.points() == [{"a": 0}]
    assert h2.n_pending() == 0


# ---------------------------------------------------------------------------
# hypothesis layer (optional dependency)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    def space_strategy():
        int_dim = st.builds(
            lambda name, lo, span, step: IntDim(name, lo, lo + span * step, step),
            st.just(""), st.integers(0, 10), st.integers(1, 12), st.integers(1, 10),
        )
        cat_dim = st.builds(
            lambda name, n: CatDim(name, tuple(f"c{i}" for i in range(n))),
            st.just(""), st.integers(2, 6),
        )
        def _name(dims):
            return SearchSpace([
                (IntDim(f"d{i}", d.lo, d.hi, d.step) if isinstance(d, IntDim)
                 else CatDim(f"d{i}", d.choices))
                for i, d in enumerate(dims)
            ])
        return st.lists(st.one_of(int_dim, cat_dim),
                        min_size=1, max_size=5).map(_name)

    @given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_encode_decode_roundtrip(space, seed):
        check_roundtrip(space, np.random.default_rng(seed))

    @given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_decode_always_valid(space, seed):
        check_decode_always_valid(space, np.random.default_rng(seed))

    @given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=40, deadline=None)
    def test_perturb_stays_on_grid(space, seed):
        check_perturb_stays_on_grid(space, np.random.default_rng(seed))

    @given(space=space_strategy(), seed=st.integers(0, 2**32 - 1),
           n=st.integers(1, 30))
    @settings(max_examples=40, deadline=None)
    def test_history_invariants(space, seed, n):
        check_history_invariants(space, np.random.default_rng(seed), n)

    @given(space=space_strategy(), seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_history_json_roundtrip(tmp_path_factory, space, seed):
        check_history_json_roundtrip(
            space, np.random.default_rng(seed),
            tmp_path_factory.mktemp("hist"))
