"""TuningDB: bucketing, hit/miss semantics, fingerprint scoping,
concurrent-writer merge, and trace-time pickup by serve/train steps."""

import json
import pathlib
import threading

import jax
import jax.numpy as jnp
import pytest

import repro.kernels.ops as ops
from repro.models.runtime import CPU_TEST, Runtime
from repro.tuning.tundb import TuningDB, bucket_shape, hardware_fingerprint

FP = {"backend": "cpu", "device_kind": "cpu", "device_count": 1,
      "machine": "x86_64", "cpu_count": 8}


def test_bucket_shape_rounds_up_to_pow2():
    assert bucket_shape({"S": 1}) == {"S": 1}
    assert bucket_shape({"S": 3}) == {"S": 4}
    assert bucket_shape({"S": 4}) == {"S": 4}
    assert bucket_shape({"S": 3000, "B": 7}) == {"S": 4096, "B": 8}
    # zero/negative pass through (sentinel dims)
    assert bucket_shape({"S": 0, "w": -1}) == {"S": 0, "w": -1}


def test_hit_returns_stored_config_and_same_bucket_aliases():
    db = TuningDB(fingerprint=FP)
    assert db.record("rmsnorm", {"rows": 100, "D": 64},
                     {"block_rows": 32}, 10.0)
    rec = db.lookup("rmsnorm", {"rows": 100, "D": 64})
    assert rec["config"] == {"block_rows": 32}
    assert rec["value"] == 10.0 and rec["kernel"] == "rmsnorm"
    assert rec["fingerprint"] == FP
    # any shape in the same pow2 bucket shares the answer; a different
    # bucket does not
    assert db.kernel_config("rmsnorm", {"rows": 128, "D": 129}) is None
    assert db.kernel_config("rmsnorm", {"rows": 65, "D": 64}) \
        == {"block_rows": 32}


def test_miss_and_fingerprint_mismatch_are_misses():
    db = TuningDB(fingerprint=FP)
    db.record("rmsnorm", {"rows": 64, "D": 64}, {"block_rows": 16}, 1.0)
    assert db.lookup("gla_scan", {"rows": 64, "D": 64}) is None
    other = TuningDB(store=db.store, fingerprint=dict(FP, device_count=4))
    other.refresh()
    # same kernel+bucket, different hardware: must NOT serve the answer
    assert other.lookup("rmsnorm", {"rows": 64, "D": 64}) is None
    assert other.lookups == 1 and other.hits == 0


def test_record_keeps_best_value():
    db = TuningDB(fingerprint=FP)
    assert db.record("k", {"S": 8}, {"chunk": 8}, 5.0)
    assert not db.record("k", {"S": 8}, {"chunk": 4}, 4.0)  # worse: kept out
    assert not db.record("k", {"S": 8}, {"chunk": 2}, 5.0)  # tie: kept out
    assert db.kernel_config("k", {"S": 8}) == {"chunk": 8}
    assert db.record("k", {"S": 8}, {"chunk": 16}, 6.0)  # strict improvement
    assert db.kernel_config("k", {"S": 8}) == {"chunk": 16}


def test_concurrent_writers_merge_via_store(tmp_path):
    path = str(tmp_path / "tundb.json")
    dbs = [TuningDB(path, fingerprint=FP) for _ in range(4)]

    def write(i):
        dbs[i].record(f"kernel{i}", {"S": 16}, {"chunk": 8 * (i + 1)},
                      float(i))

    threads = [threading.Thread(target=write, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # a fresh reader sees the union: locked read-merge-write, no clobber
    fresh = TuningDB(path, fingerprint=FP)
    assert len(fresh) == 4
    for i in range(4):
        assert fresh.kernel_config(f"kernel{i}", {"S": 16}) \
            == {"chunk": 8 * (i + 1)}
    # and refresh() merges other writers' records into a live instance
    dbs[0].refresh()
    assert len(dbs[0]) == 4


def test_persisted_db_round_trips(tmp_path):
    path = str(tmp_path / "tundb.json")
    db = TuningDB(path, fingerprint=FP)
    db.record("rmsnorm", {"rows": 64, "D": 64}, {"block_rows": 16}, 2.0,
              fidelity=0.5, job_id="job-1", timestamp=123.0)
    raw = json.loads(pathlib.Path(path).read_text())
    assert len(raw) == 1
    rec = TuningDB(path, fingerprint=FP).lookup(
        "rmsnorm", {"rows": 64, "D": 64})
    assert rec["fidelity"] == 0.5 and rec["job_id"] == "job-1"
    assert rec["timestamp"] == 123.0 and rec["bucket"] == {"rows": 64, "D": 64}


def test_db_is_identity_hashable_and_runtime_stays_static_arg_safe():
    db = TuningDB(fingerprint=FP)
    db2 = TuningDB(fingerprint=FP)
    assert db != db2 and db == db and hash(db) == hash(db)
    import dataclasses
    rt = dataclasses.replace(CPU_TEST, tuning_db=db)
    assert hash(rt) != 0 or True  # hashable: no TypeError
    assert rt != dataclasses.replace(CPU_TEST, tuning_db=db2)


def test_default_runtime_carries_no_db():
    # golden ask/tell traces and every historical code path run with
    # tuning_db=None; the default must stay None
    assert Runtime().tuning_db is None and CPU_TEST.tuning_db is None


def test_hardware_fingerprint_fields():
    fp = hardware_fingerprint()
    assert set(fp) == {"backend", "device_kind", "device_count", "machine",
                       "cpu_count"}
    assert fp["device_count"] >= 1


def _spy_tuned(monkeypatch):
    seen = {}
    orig = ops._tuned

    def spy(db, kernel, dims, defaults):
        out = orig(db, kernel, dims, defaults)
        if db is not None:
            seen[kernel] = {"dims": dict(dims), "chosen": dict(out)}
        return out

    monkeypatch.setattr(ops, "_tuned", spy)
    return seen


@pytest.fixture
def tiny_lm():
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.models.params import split_params

    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def test_serve_step_picks_up_tuned_tiles_at_trace_time(monkeypatch, tiny_lm):
    from repro.serve.serve_step import make_prefill_step

    cfg, model, params = tiny_lm
    rt = Runtime(compute_dtype="f32", attn_impl="pallas")
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32)}
    from repro.models.params import split_params
    cache, _ = split_params(model.init_cache(1, 32))

    seen = _spy_tuned(monkeypatch)
    # probe lower with an empty DB to learn the traced dims (a miss:
    # heuristic defaults survive)
    db = TuningDB(fingerprint=hardware_fingerprint())
    step = make_prefill_step(model, rt, tuning_db=db)
    jax.jit(step).lower(params, batch, cache)
    dims = seen["flash_attention"]["dims"]
    assert seen["flash_attention"]["chosen"] == {"block_q": rt.block_q,
                                                 "block_kv": rt.block_kv}
    assert db.lookups > 0 and db.hits == 0

    # now record an answer at exactly those dims: the rebuilt step must
    # trace with the tuned tiles
    db.record("flash_attention", dims, {"block_q": 8, "block_kv": 8}, 99.0)
    seen.clear()
    step2 = make_prefill_step(model, rt, tuning_db=db)
    jax.jit(step2).lower(params, batch, cache)
    assert seen["flash_attention"]["chosen"] == {"block_q": 8, "block_kv": 8}
    assert db.hits > 0


def test_train_step_picks_up_tuned_tiles_at_trace_time(monkeypatch, tiny_lm):
    from repro.optim.optimizer import OptimizerConfig, adamw_init
    from repro.train.train_step import make_train_step

    cfg, model, params = tiny_lm
    rt = Runtime(compute_dtype="f32", attn_impl="pallas")
    opt_cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=1,
                              total_steps=2)
    opt_state = adamw_init(params, opt_cfg)
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32),
             "targets": jnp.zeros((1, 16), jnp.int32)}

    seen = _spy_tuned(monkeypatch)
    db = TuningDB(fingerprint=hardware_fingerprint())
    step = make_train_step(model, opt_cfg, rt, tuning_db=db)
    jax.jit(step).lower(params, opt_state, batch)
    dims = seen["flash_attention"]["dims"]

    db.record("flash_attention", dims, {"block_q": 16, "block_kv": 8}, 1.0)
    seen.clear()
    step2 = make_train_step(model, opt_cfg, rt, tuning_db=db)
    jax.jit(step2).lower(params, opt_state, batch)
    assert seen["flash_attention"]["chosen"] == {"block_q": 16, "block_kv": 8}


def test_no_db_consults_nothing(monkeypatch, tiny_lm):
    from repro.serve.serve_step import make_prefill_step

    cfg, model, params = tiny_lm
    rt = Runtime(compute_dtype="f32", attn_impl="pallas")
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32)}
    from repro.models.params import split_params
    cache, _ = split_params(model.init_cache(1, 32))
    seen = _spy_tuned(monkeypatch)
    jax.jit(make_prefill_step(model, rt)).lower(params, batch, cache)
    assert seen == {}  # tuning_db=None: the spy records only real consults
