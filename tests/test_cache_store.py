"""Disk-backed memo persistence: JsonCacheStore atomicity + locking,
MemoCache round-trips across executor instances, concurrent writers
merging instead of clobbering, and the 0-re-evaluation guarantee for a
repeated tuning run."""
import json
import math
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.core import IntDim, SearchSpace, Tuner, TunerConfig
from repro.tuning.cache import JsonCacheStore, NullCacheStore, open_store
from repro.tuning.executor import EvalResult, EvaluationExecutor, MemoCache
from repro.tuning.objective import CountingEvaluator


def small_space() -> SearchSpace:
    return SearchSpace([IntDim("a", 0, 9), IntDim("b", 0, 9)])


# ---------------------------------------------------------------------------
# store layer
# ---------------------------------------------------------------------------

def test_json_store_roundtrip_and_merge(tmp_path):
    store = JsonCacheStore(tmp_path / "c.json")
    assert store.load() == {}
    store.put("k1", {"v": 1})
    store.put("k2", {"v": 2})
    assert store.load() == {"k1": {"v": 1}, "k2": {"v": 2}}
    # a second store instance on the same path merges, not clobbers
    other = JsonCacheStore(tmp_path / "c.json")
    other.put("k3", {"v": 3})
    assert set(store.load()) == {"k1", "k2", "k3"}
    # no torn temp files left behind
    assert not (tmp_path / "c.json.tmp").exists()


def test_json_store_neg_inf_value_roundtrip(tmp_path):
    """Failed configurations (-inf) must survive the JSON round trip."""
    store = JsonCacheStore(tmp_path / "c.json")
    store.put("oom", {"value": -math.inf, "point": {"a": 1}})
    assert store.load()["oom"]["value"] == -math.inf


def test_json_store_concurrent_writers_union(tmp_path):
    """N writers, each with its own store instance, racing read-merge-write
    on one file: the flock serializes them and every key survives."""
    path = tmp_path / "c.json"

    def writer(wid):
        store = JsonCacheStore(path)  # own fd, contends on the lock file
        for i in range(5):
            store.put(f"w{wid}-{i}", {"wid": wid, "i": i})

    with ThreadPoolExecutor(max_workers=8) as pool:
        list(pool.map(writer, range(8)))
    data = JsonCacheStore(path).load()
    assert len(data) == 40
    assert json.loads(path.read_text()) == data  # file itself is coherent


def test_open_store_dispatch(tmp_path):
    assert isinstance(open_store(None), NullCacheStore)
    assert isinstance(open_store(tmp_path / "x.json"), JsonCacheStore)
    null = open_store(None)
    null.put("k", {})
    assert null.load() == {}


# ---------------------------------------------------------------------------
# MemoCache on top of the store
# ---------------------------------------------------------------------------

def test_memo_cache_disk_roundtrip(tmp_path):
    space = small_space()
    store = JsonCacheStore(tmp_path / "memo.json")
    cache = MemoCache(store=store)
    cache.put(space.key({"a": 1, "b": 2}),
              EvalResult({"a": 1, "b": 2}, 5.0, 0.25, {"m": 1}))
    # a fresh cache (new process, conceptually) seeds itself from disk
    fresh = MemoCache(store=JsonCacheStore(tmp_path / "memo.json"))
    assert fresh.load_store(space) == 1
    hit = fresh.get(space.key({"a": 1, "b": 2}))
    assert hit.value == 5.0 and hit.cost_seconds == 0.25 and hit.meta == {"m": 1}


def test_executor_memo_survives_restart(tmp_path):
    """A new executor pointed at the same cache file re-evaluates nothing."""
    space = small_space()
    path = str(tmp_path / "memo.json")
    counting = CountingEvaluator(lambda p: float(p["a"] * 10 + p["b"]))
    pts = [{"a": i, "b": i} for i in range(4)]

    ex1 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    out1 = ex1.evaluate(pts)
    ex1.close()
    assert counting.calls == 4

    ex2 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    out2 = ex2.evaluate(pts)
    ex2.close()
    assert counting.calls == 4  # zero re-evaluations
    assert [r.value for r in out2] == [r.value for r in out1]
    assert all(r.meta.get("memoized") for r in out2)


def test_executor_submit_next_completed_with_disk_cache(tmp_path):
    """The completion-driven protocol hits the disk cache too: cached
    submissions come back already done."""
    space = small_space()
    path = str(tmp_path / "memo.json")
    counting = CountingEvaluator(lambda p: float(p["a"]))
    pts = [{"a": i, "b": 0} for i in range(3)]

    ex1 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    for p in ex1.as_completed(ex1.submit(pts)):
        assert p.result().value == pytest.approx(float(p.point["a"]))
    ex1.close()
    assert counting.calls == 3

    ex2 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    pend2 = ex2.submit(pts)
    assert all(p.done() for p in pend2)  # resolved straight from disk
    assert counting.calls == 3
    ex2.close()


# ---------------------------------------------------------------------------
# end to end: second tuning run hits the cache, 0 re-evaluations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,par", [("random", 1), ("exhaustive", 4)])
def test_second_tuning_run_zero_reevaluations(tmp_path, algo, par):
    path = str(tmp_path / "memo.json")
    counting = CountingEvaluator(lambda p: float(p["a"] * 10 + p["b"]))

    def run():
        t = Tuner(counting, small_space(),
                  TunerConfig(algorithm=algo, budget=10, seed=0,
                              verbose=False, parallelism=par,
                              memo_cache_path=path))
        h = t.run()
        t.close()
        return h

    h1 = run()
    first = counting.calls
    assert first == 10
    h2 = run()
    assert counting.calls == first  # disk memo: 0 re-evaluations
    assert sorted(e.value for e in h2.evals) == sorted(
        e.value for e in h1.evals)
    # cache hits are labeled so a run report can show what was reused
    assert all(e.meta.get("memoized") for e in h2.evals)


def test_roofline_evaluator_reads_legacy_cache_format(tmp_path):
    """The store's on-disk format is the evaluator's historical plain-JSON
    dict, so pre-existing cache files keep working."""
    from repro.tuning.evaluator import RooflineEvaluator

    legacy = tmp_path / "tune_cache.json"
    legacy.write_text(json.dumps({"somekey": {"roofline": {"x": 1}}}))
    ev = RooflineEvaluator("qwen2-0.5b", "train_4k", cache_path=str(legacy))
    assert ev._cache == {"somekey": {"roofline": {"x": 1}}}
