"""Disk-backed memo persistence: JsonCacheStore atomicity + locking,
MemoCache round-trips across executor instances, concurrent writers
merging instead of clobbering, the 0-re-evaluation guarantee for a
repeated tuning run — plus the hardening contracts: corrupt-file
quarantine, loud serialization failure at put time (no default=str
corruption), batched flushes (one store write per completion drain),
cross-process contention, and the guarantee that timeout/preempt
placeholders never reach the disk store."""
import json
import math
import multiprocessing
import time
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.core import IntDim, SearchSpace, Tuner, TunerConfig
from repro.tuning.cache import JsonCacheStore, NullCacheStore, open_store
from repro.tuning.executor import EvalResult, EvaluationExecutor, MemoCache
from repro.tuning.objective import CountingEvaluator


def small_space() -> SearchSpace:
    return SearchSpace([IntDim("a", 0, 9), IntDim("b", 0, 9)])


# ---------------------------------------------------------------------------
# store layer
# ---------------------------------------------------------------------------

def test_json_store_roundtrip_and_merge(tmp_path):
    store = JsonCacheStore(tmp_path / "c.json")
    assert store.load() == {}
    store.put("k1", {"v": 1})
    store.put("k2", {"v": 2})
    assert store.load() == {"k1": {"v": 1}, "k2": {"v": 2}}
    # a second store instance on the same path merges, not clobbers
    other = JsonCacheStore(tmp_path / "c.json")
    other.put("k3", {"v": 3})
    assert set(store.load()) == {"k1", "k2", "k3"}
    # no torn temp files left behind
    assert not (tmp_path / "c.json.tmp").exists()


def test_json_store_neg_inf_value_roundtrip(tmp_path):
    """Failed configurations (-inf) must survive the JSON round trip."""
    store = JsonCacheStore(tmp_path / "c.json")
    store.put("oom", {"value": -math.inf, "point": {"a": 1}})
    assert store.load()["oom"]["value"] == -math.inf


def test_json_store_concurrent_writers_union(tmp_path):
    """N writers, each with its own store instance, racing read-merge-write
    on one file: the flock serializes them and every key survives."""
    path = tmp_path / "c.json"

    def writer(wid):
        store = JsonCacheStore(path)  # own fd, contends on the lock file
        for i in range(5):
            store.put(f"w{wid}-{i}", {"wid": wid, "i": i})

    with ThreadPoolExecutor(max_workers=8) as pool:
        list(pool.map(writer, range(8)))
    data = JsonCacheStore(path).load()
    assert len(data) == 40
    assert json.loads(path.read_text()) == data  # file itself is coherent


def test_corrupt_cache_file_is_quarantined_not_fatal(tmp_path):
    """A torn/corrupt cache file (host died mid-write) must not kill the
    run: it is renamed to .corrupt, a warning fires, and the store
    continues empty."""
    path = tmp_path / "c.json"
    path.write_text('{"k1": {"v": 1}, "k2": TORN')  # mid-write death
    store = JsonCacheStore(path)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert store.load() == {}
    quarantined = tmp_path / "c.json.corrupt"
    assert quarantined.exists()  # kept for post-mortem, byte-identical
    assert quarantined.read_text() == '{"k1": {"v": 1}, "k2": TORN'
    assert not path.exists()
    # and the store is fully usable afterwards
    store.put("k3", {"v": 3})
    assert store.load() == {"k3": {"v": 3}}


def test_corrupt_cache_file_during_put_recovers(tmp_path):
    """put() read-merges under the lock; a corrupt file there is
    quarantined too and the put still lands."""
    path = tmp_path / "c.json"
    path.write_text("not json at all")
    store = JsonCacheStore(path)
    with pytest.warns(RuntimeWarning):
        store.put("k", {"v": 1})
    assert json.loads(path.read_text()) == {"k": {"v": 1}}


def test_non_serializable_record_fails_loudly_at_put(tmp_path):
    """default=str used to silently stringify non-JSON fields — the
    record *looked* cached but reloaded corrupted.  Now it's a TypeError
    naming the key, and the store file is untouched."""
    store = JsonCacheStore(tmp_path / "c.json")
    store.put("good", {"v": 1})
    with pytest.raises(TypeError, match="badkey"):
        store.put("badkey", {"v": object()})
    with pytest.raises(TypeError, match="round trip"):
        store.put_many({"k1": {"v": 2}, "k2": {"v": {1, 2}}})
    # json.dumps would SUCCEED on these — and corrupt them on reload
    # (tuple -> list, int key -> str key); they must be rejected too
    with pytest.raises(TypeError, match="tuple"):
        store.put("tup", {"tile": (512, 128)})
    with pytest.raises(TypeError, match="non-string key"):
        store.put("intkey", {"meta": {1: "x"}})
    assert store.load() == {"good": {"v": 1}}  # nothing half-written


def test_memo_cache_rejects_non_serializable_meta_at_put_time(tmp_path):
    """Buffered mode must surface the error at put() — pointing at the
    evaluation that produced the bad record — not at some later flush."""
    space = small_space()
    cache = MemoCache(store=JsonCacheStore(tmp_path / "m.json"),
                      autoflush=False)
    with pytest.raises(TypeError, match="round trip"):
        cache.put(space.key({"a": 1, "b": 1}),
                  EvalResult({"a": 1, "b": 1}, 1.0, 0.1,
                             {"handle": object()}))
    cache.flush()
    assert JsonCacheStore(tmp_path / "m.json").load() == {}


def test_cached_record_reloads_equal_to_what_was_stored(tmp_path):
    """Regression for the default=str corruption: a record must
    round-trip *equal*, including non-finite floats and nesting."""
    space = small_space()
    meta = {"roofline": {"compute_s": 0.125, "fits": True},
            "notes": ["a", 1, 2.5, None], "err": -math.inf}
    rec = EvalResult({"a": 3, "b": 4}, -math.inf, 1.5, meta)
    cache = MemoCache(store=JsonCacheStore(tmp_path / "m.json"))
    cache.put(space.key(rec.point), rec)
    fresh = MemoCache(store=JsonCacheStore(tmp_path / "m.json"))
    fresh.load_store(space)
    hit = fresh.get(space.key(rec.point))
    assert hit.point == rec.point
    assert hit.value == rec.value
    assert hit.cost_seconds == rec.cost_seconds
    assert hit.meta == rec.meta  # exact, not stringified


def test_open_store_dispatch(tmp_path):
    assert isinstance(open_store(None), NullCacheStore)
    assert isinstance(open_store(tmp_path / "x.json"), JsonCacheStore)
    null = open_store(None)
    null.put("k", {})
    assert null.load() == {}


# ---------------------------------------------------------------------------
# MemoCache on top of the store
# ---------------------------------------------------------------------------

def test_memo_cache_disk_roundtrip(tmp_path):
    space = small_space()
    store = JsonCacheStore(tmp_path / "memo.json")
    cache = MemoCache(store=store)
    cache.put(space.key({"a": 1, "b": 2}),
              EvalResult({"a": 1, "b": 2}, 5.0, 0.25, {"m": 1}))
    # a fresh cache (new process, conceptually) seeds itself from disk
    fresh = MemoCache(store=JsonCacheStore(tmp_path / "memo.json"))
    assert fresh.load_store(space) == 1
    hit = fresh.get(space.key({"a": 1, "b": 2}))
    assert hit.value == 5.0 and hit.cost_seconds == 0.25 and hit.meta == {"m": 1}


def test_executor_memo_survives_restart(tmp_path):
    """A new executor pointed at the same cache file re-evaluates nothing."""
    space = small_space()
    path = str(tmp_path / "memo.json")
    counting = CountingEvaluator(lambda p: float(p["a"] * 10 + p["b"]))
    pts = [{"a": i, "b": i} for i in range(4)]

    ex1 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    out1 = ex1.evaluate(pts)
    ex1.close()
    assert counting.calls == 4

    ex2 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    out2 = ex2.evaluate(pts)
    ex2.close()
    assert counting.calls == 4  # zero re-evaluations
    assert [r.value for r in out2] == [r.value for r in out1]
    assert all(r.meta.get("memoized") for r in out2)


def test_executor_submit_next_completed_with_disk_cache(tmp_path):
    """The completion-driven protocol hits the disk cache too: cached
    submissions come back already done."""
    space = small_space()
    path = str(tmp_path / "memo.json")
    counting = CountingEvaluator(lambda p: float(p["a"]))
    pts = [{"a": i, "b": 0} for i in range(3)]

    ex1 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    for p in ex1.as_completed(ex1.submit(pts)):
        assert p.result().value == pytest.approx(float(p.point["a"]))
    ex1.close()
    assert counting.calls == 3

    ex2 = EvaluationExecutor(counting, space, parallelism=2, cache_path=path)
    pend2 = ex2.submit(pts)
    assert all(p.done() for p in pend2)  # resolved straight from disk
    assert counting.calls == 3
    ex2.close()


# ---------------------------------------------------------------------------
# end to end: second tuning run hits the cache, 0 re-evaluations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,par", [("random", 1), ("exhaustive", 4)])
def test_second_tuning_run_zero_reevaluations(tmp_path, algo, par):
    path = str(tmp_path / "memo.json")
    counting = CountingEvaluator(lambda p: float(p["a"] * 10 + p["b"]))

    def run():
        t = Tuner(counting, small_space(),
                  TunerConfig(algorithm=algo, budget=10, seed=0,
                              verbose=False, parallelism=par,
                              memo_cache_path=path))
        h = t.run()
        t.close()
        return h

    h1 = run()
    first = counting.calls
    assert first == 10
    h2 = run()
    assert counting.calls == first  # disk memo: 0 re-evaluations
    assert sorted(e.value for e in h2.evals) == sorted(
        e.value for e in h1.evals)
    # cache hits are labeled so a run report can show what was reused
    assert all(e.meta.get("memoized") for e in h2.evals)


def test_executor_evaluate_batch_is_single_flush(tmp_path):
    """N completed evaluations persist as ONE store write (read-merge-
    write of the whole file per put is the O(N^2) pattern this kills)."""
    space = small_space()
    path = str(tmp_path / "memo.json")
    ex = EvaluationExecutor(lambda p: float(p["a"]), space, parallelism=4,
                            cache_path=path)
    ex.evaluate([{"a": i, "b": 0} for i in range(8)])
    assert ex.cache.flushes == 1  # one put_many for the whole batch
    assert len(JsonCacheStore(path).load()) == 8
    ex.close()
    assert ex.cache.flushes == 1  # close had nothing left to write


def test_executor_async_drain_flushes_at_most_once_per_drain(tmp_path):
    """The completion-driven path batches too: each next_completed drain
    is at most one flush, and simultaneous completions share it."""
    space = small_space()
    path = str(tmp_path / "memo.json")
    ex = EvaluationExecutor(lambda p: float(p["a"]), space, parallelism=4,
                            cache_path=path)
    pend = ex.submit([{"a": i, "b": 1} for i in range(8)])
    drains = 0
    remaining = list(pend)
    while remaining:
        done = ex.next_completed(remaining)
        remaining.remove(done)
        drains += 1
    assert ex.cache.flushes <= drains  # never more writes than drains
    assert len(JsonCacheStore(path).load()) == 8  # nothing lost
    ex.close()


def test_serial_backend_still_persists_via_submit_flush(tmp_path):
    space = small_space()
    path = str(tmp_path / "memo.json")
    ex = EvaluationExecutor(lambda p: float(p["a"]), space, parallelism=1,
                            cache_path=path)
    ex.submit([{"a": i, "b": 2} for i in range(3)])
    assert ex.cache.flushes == 1  # the serial submit is one drain
    assert len(JsonCacheStore(path).load()) == 3
    ex.close()


def _contending_writer(path, wid, n_keys):
    store = JsonCacheStore(path)
    for i in range(n_keys):
        store.put(f"w{wid}-{i}", {"wid": wid, "i": i})
        store.put("shared", {"winner": wid})  # contested key


def test_cross_process_contention_loses_no_keys(tmp_path):
    """Two real processes hammering one store file: union across keys,
    a coherent parse, and last-writer-wins (one writer's intact record,
    never an interleaving) on the contested key."""
    path = tmp_path / "c.json"
    procs = [multiprocessing.Process(target=_contending_writer,
                                     args=(path, wid, 10))
             for wid in range(2)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=30)
        assert p.exitcode == 0
    data = json.loads(path.read_text())  # file is coherent JSON
    assert {k for k in data if k != "shared"} \
        == {f"w{wid}-{i}" for wid in range(2) for i in range(10)}
    assert data["shared"] in ({"winner": 0}, {"winner": 1})
    for wid in range(2):
        for i in range(10):
            assert data[f"w{wid}-{i}"] == {"wid": wid, "i": i}


def test_timeout_and_preempt_placeholders_never_reach_disk(tmp_path):
    """A -inf under this run's timeout, and a preempted-before-start
    placeholder, are run-local artifacts: the cross-run store must stay
    clean so a later run measures those points for real."""
    space = small_space()
    path = str(tmp_path / "memo.json")

    def objective(p):
        if p["a"] == 9:
            time.sleep(0.5)  # will blow the 0.1s timeout
        return float(p["a"])

    ex = EvaluationExecutor(objective, space, parallelism=1,
                            backend="thread", timeout=0.1, cache_path=path)
    slow, queued, fast = ex.submit(
        [{"a": 9, "b": 0}, {"a": 1, "b": 0}, {"a": 2, "b": 0}])
    assert ex.preempt(queued) == "cancelled"  # 1-wide pool: still queued
    done = []
    remaining = [slow, fast]
    while remaining:
        p = ex.next_completed(remaining)
        remaining.remove(p)
        done.append(p)
    by_a = {p.point["a"]: p.result() for p in done}
    assert by_a[9].meta.get("timeout") and by_a[9].value == -math.inf
    assert by_a[2].value == 2.0
    ex.close()
    stored = JsonCacheStore(path).load()
    stored_as = {json.loads(k)[0] for k in stored}
    assert stored_as == {2}  # the real measurement only: no 9, no 1
    # in-memory memo still knows the timeout for THIS run
    assert ex.cache.get(space.key({"a": 9, "b": 0})).meta.get("timeout")


def test_roofline_evaluator_reads_legacy_cache_format(tmp_path):
    """The store's on-disk format is the evaluator's historical plain-JSON
    dict, so pre-existing cache files keep working."""
    from repro.tuning.evaluator import RooflineEvaluator

    legacy = tmp_path / "tune_cache.json"
    legacy.write_text(json.dumps({"somekey": {"roofline": {"x": 1}}}))
    ev = RooflineEvaluator("qwen2-0.5b", "train_4k", cache_path=str(legacy))
    assert ev._cache == {"somekey": {"roofline": {"x": 1}}}
