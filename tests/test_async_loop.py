"""Completion-driven tuner loop: out-of-order completion, mid-stream
checkpoint/resume with stale in-flight points, NMS speculative-probe
reconciliation when probes complete late, and wall-clock bounding of
in-flight work.

Parallel completion *order* is inherently nondeterministic, so these
tests assert semantic invariants (value/point consistency, budget
accounting, state-machine equivalence, uniqueness after resume) rather
than full trace equality; bit-for-bit trace pinning lives in
test_executor.py at ``parallelism=1``.
"""
import json
import math
import pathlib
import time

import pytest

from repro.core import ENGINES, History, Observation, Tuner, TunerConfig
from repro.core.space import SearchSpace

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "ask_tell_traces.json")
    .read_text())

ALGOS = ["bo", "ga", "nms", "random", "exhaustive"]


def golden_space() -> SearchSpace:
    return SearchSpace.from_dicts(GOLDEN["space"])


def golden_objective(p):
    a, b, c = p["inter_op"], p["intra_op"], p["build"]
    return float(50.0 * pow(2.718281828, -((a - 11) / 5.0) ** 2)
                 + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)


def skewed_objective(p):
    """Deterministic value with a skewed simulated measurement cost: a
    quarter of the grid is 10x slower, which is exactly the shape that
    stalls a batch-barrier loop."""
    if (p["inter_op"] + p["intra_op"]) % 4 == 0:
        time.sleep(0.10)
    else:
        time.sleep(0.01)
    return golden_objective(p)


# ---------------------------------------------------------------------------
# completion-driven loop semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("seed", [0, 3])
def test_async_parallelism_1_reproduces_seed_trace(algo, seed):
    """The completion-driven loop at parallelism=1 degenerates to the
    historical sequential loop, bit-for-bit."""
    trace = GOLDEN["traces"][f"{algo}:{seed}"]
    t = Tuner(golden_objective, golden_space(),
              TunerConfig(algorithm=algo, budget=18, seed=seed,
                          verbose=False, parallelism=1, loop="async"))
    h = t.run()
    assert h.points() == trace["points"]
    assert [e.value for e in h.evals] == pytest.approx(trace["values"])


@pytest.mark.parametrize("algo", ["bo", "ga", "nms", "random"])
def test_async_out_of_order_results_stay_consistent(algo):
    """With skewed costs, completions land out of submission order; every
    recorded (point, value) pair must still correspond, the budget must be
    spent exactly, and no in-flight marks may survive the run."""
    t = Tuner(skewed_objective, golden_space(),
              TunerConfig(algorithm=algo, budget=16, seed=0,
                          verbose=False, parallelism=4))
    h = t.run()
    t.close()
    assert len(h) == 16
    assert h.n_pending() == 0
    for e in h.evals:
        assert e.value == pytest.approx(golden_objective(e.point))
    # slow vs fast cost attribution survived the reordering
    paid = [e for e in h.evals if e.cost_seconds > 0]
    assert paid, "no evaluation recorded its measurement cost"
    assert h.best().value >= 50.0


def test_async_cost_seconds_reach_engine():
    """Measured evaluation cost is threaded through tell so engines can be
    wall-clock-aware."""
    t = Tuner(skewed_objective, golden_space(),
              TunerConfig(algorithm="random", budget=6, seed=0,
                          verbose=False, parallelism=2))
    t.run()
    t.close()
    assert t.engine.mean_cost_seconds > 0.0


def test_async_checkpoint_resume_mid_stream_with_stale_inflight(tmp_path):
    """Abort while several skew-delayed evaluations are in flight: the
    checkpoint holds only completed results, stale in-flight points leave
    no pending marks, and a resumed run finishes the budget without
    re-measuring anything it already has."""
    ck = tmp_path / "t.json"
    state = {"evals": 0}

    def obj(p):
        state["evals"] += 1
        if state["evals"] == 7:
            raise KeyboardInterrupt()  # not failure-isolated: a real abort
        return skewed_objective(p)

    t1 = Tuner(obj, golden_space(),
               TunerConfig(algorithm="random", budget=16, seed=2,
                           verbose=False, parallelism=1,
                           checkpoint_path=str(ck)))
    with pytest.raises(KeyboardInterrupt):
        t1.run()
    assert 0 < len(t1.history) < 16
    assert t1.history.n_pending() == 0  # stale in-flight marks cleaned up
    saved = json.loads(ck.read_text())
    assert len(saved) == len(t1.history)
    assert [r["point"] for r in saved] == t1.history.points()

    t2 = Tuner(golden_objective, golden_space(),
               TunerConfig(algorithm="random", budget=16, seed=2,
                           verbose=False, parallelism=4,
                           checkpoint_path=str(ck)))
    h2 = t2.run()
    t2.close()
    assert len(h2) == 16
    assert h2.points()[:len(t1.history)] == t1.history.points()
    keys = {golden_space().key(p) for p in h2.points()}
    assert len(keys) == 16  # nothing measured twice after the resume


def test_async_wall_clock_bounds_inflight_work():
    """A hung evaluation must not blow past wall_clock_budget: work still
    unfinished at the deadline is abandoned — the run ends on time and the
    hung configuration is NOT falsely recorded as a failure (a deadline is
    a budget artifact of this run, not a property of the point)."""
    def obj(p):
        if p["inter_op"] == 1:
            time.sleep(8)  # hung measurement
        return golden_objective(p)

    space = golden_space()
    t = Tuner(obj, space,
              TunerConfig(algorithm="exhaustive", budget=10_000, seed=0,
                          verbose=False, parallelism=2,
                          wall_clock_budget=0.6))
    t0 = time.time()
    h = t.run()
    t.close()
    elapsed = time.time() - t0
    assert elapsed < 5.0, f"hung eval blew past the wall clock ({elapsed:.1f}s)"
    assert h.n_pending() == 0
    hung = [e for e in h.evals if e.point["inter_op"] == 1]
    assert not hung, f"abandoned eval falsely recorded: {hung}"
    assert all(math.isfinite(e.value) for e in h.evals)


def test_wall_clock_bounds_hung_eval_even_at_parallelism_1():
    """The serial backend cannot abandon a running evaluation, so a
    wall-clock budget must select a pool backend even at parallelism=1."""
    def obj(p):
        time.sleep(8)
        return 1.0

    t = Tuner(obj, golden_space(),
              TunerConfig(algorithm="random", budget=10, seed=0,
                          verbose=False, parallelism=1,
                          wall_clock_budget=0.5))
    assert t.executor.backend == "thread"
    t0 = time.time()
    h = t.run()
    t.close()
    assert time.time() - t0 < 5.0
    assert len(h) == 0 and h.n_pending() == 0

    # the same contract must hold when the budget arrives at run() time
    t2 = Tuner(obj, golden_space(),
               TunerConfig(algorithm="random", budget=10, seed=0,
                           verbose=False, parallelism=1))
    assert t2.executor.backend == "serial"
    t0 = time.time()
    h2 = t2.run(wall_clock=0.5)
    t2.close()
    assert t2.executor.backend == "thread"  # swapped before the loop started
    assert time.time() - t0 < 5.0
    assert len(h2) == 0 and h2.n_pending() == 0


def test_eval_timeout_verdict_not_persisted_to_disk(tmp_path):
    """A per-eval timeout scores -inf for this run but must not poison the
    cross-run disk cache: a later run (maybe with a larger timeout) gets to
    measure the configuration for real."""
    memo = str(tmp_path / "memo.json")
    calls = {"n": 0}

    def obj(p):
        calls["n"] += 1
        if p["inter_op"] == 1 and calls["n"] == 1:
            time.sleep(8)  # hung only on the first attempt
        return golden_objective(p)

    space = golden_space()
    pts = [{"inter_op": 1, "intra_op": 0, "build": 1}]
    from repro.tuning.executor import EvaluationExecutor

    ex1 = EvaluationExecutor(obj, space, parallelism=1, timeout=0.3,
                             cache_path=memo)
    out = ex1.evaluate(pts)
    ex1.close()
    assert out[0].value == -math.inf and out[0].meta.get("timeout")
    # the -inf verdict is memoized for THIS executor...
    assert ex1.cache.get(space.key(pts[0])) is not None
    # ...but a fresh run from the same disk cache re-measures, and succeeds
    ex2 = EvaluationExecutor(obj, space, parallelism=1, timeout=5.0,
                             cache_path=memo)
    out2 = ex2.evaluate(pts)
    ex2.close()
    assert out2[0].value == pytest.approx(golden_objective(pts[0]))
    assert not out2[0].meta.get("memoized")


def test_async_engine_exhaustion_ends_cleanly():
    from repro.core import IntDim
    space = SearchSpace([IntDim("a", 0, 3, 1)])
    t = Tuner(lambda p: float(p["a"]), space,
              TunerConfig(algorithm="exhaustive", budget=100, seed=0,
                          verbose=False, parallelism=3))
    h = t.run()
    t.close()
    assert len(h) == 4  # the whole grid, exactly once
    assert h.best().point["a"] == 3


# ---------------------------------------------------------------------------
# NMS speculative probes completing late
# ---------------------------------------------------------------------------

def _drive(engine, tell_order, budget=30):
    """Run an engine manually, telling each batch in a caller-chosen order;
    returns the sequence of asked batches (keyed)."""
    space = engine.space
    h = History(space)
    asked = []
    while len(h) < budget:
        batch = engine.ask(4, h)
        if not batch:
            break
        asked.append([space.key(p) for p in batch])
        results = [(p, golden_objective(p)) for p in batch]
        for p, v in tell_order(results):
            engine.tell([Observation(point=p, value=v)])  # completion order
            h.add(p, v)
    return asked


def test_nms_late_speculative_probes_reconcile():
    """Telling speculative probes before their primary (worst-case
    completion order) must leave the NMS state machine in the same state
    as in-order completion: the asked-batch sequences stay identical."""
    in_order = _drive(ENGINES["nms"](golden_space(), seed=1),
                      lambda results: results)
    reversed_ = _drive(ENGINES["nms"](golden_space(), seed=1),
                       lambda results: list(reversed(results)))
    assert in_order == reversed_


def test_nms_probe_arriving_before_primary_is_buffered():
    """A speculative probe told before the primary is buffered, not lost:
    once the primary arrives, both are consumed and the machine advances
    (the next ask changes)."""
    space = golden_space()
    eng = ENGINES["nms"](space, seed=1)
    h = History(space)
    # finish init so the machine is in the reflect phase with speculation
    while eng._phase == "init":
        batch = eng.ask(4, h)
        for p in batch:
            eng.tell([Observation(point=p, value=golden_objective(p))])
            h.add(p, golden_objective(p))
    batch = eng.ask(4, h)
    assert len(batch) >= 2, "reflect phase should speculate"
    primary, probes = batch[0], batch[1:]
    before = space.key(eng._primary())
    # late primary: tell every probe first — machine must not advance
    for p in probes:
        eng.tell([Observation(point=p, value=golden_objective(p))])
        h.add(p, golden_objective(p))
    assert space.key(eng._primary()) == before
    assert all(space.key(p) in eng._told for p in probes)
    # primary lands: machine advances, consuming buffered probes it needs
    eng.tell([Observation(point=primary, value=golden_objective(primary))])
    h.add(primary, golden_objective(primary))
    assert space.key(eng._primary()) != before
