"""Validation of the paper's own claims on measured tuning workloads
(EXPERIMENTS.md §Paper-validation; faster variants of benchmarks/fig5).

Claims (paper §4.2-§4.3, §6):
  1. BO delivers the best (or tied-best) throughput on the majority of
     workloads within a 50-iteration budget.
  2. BO samples (near-)100% of every parameter's tunable range; GA covers
     the least; NMS sits between (Table 2).
  3. No single algorithm wins on every workload.
"""
import numpy as np
import pytest

from repro.core import SearchSpace, Tuner, TunerConfig
from benchmarks.workloads import MEASURED_WORKLOADS, surrogate_objective

pytestmark = pytest.mark.slow  # full 50-iteration tuning runs per engine/workload

ALGOS = ("bo", "ga", "nms")


def _run(workload, algo, seed, budget=50):
    space = SearchSpace.from_dicts(workload["space"])
    obj = surrogate_objective(workload)
    t = Tuner(obj, space, TunerConfig(algorithm=algo, budget=budget,
                                      seed=seed, verbose=False))
    return t.run()


@pytest.mark.parametrize("workload", MEASURED_WORKLOADS,
                         ids=[w["name"] for w in MEASURED_WORKLOADS])
def test_all_engines_complete_budget(workload):
    for algo in ALGOS:
        h = _run(workload, algo, seed=0, budget=25)
        assert len(h) == 25
        assert np.isfinite(h.best().value)


def test_bo_wins_majority_of_workloads():
    wins = 0
    for w in MEASURED_WORKLOADS:
        scores = {a: np.mean([_run(w, a, s).best().value for s in (0, 1)])
                  for a in ALGOS}
        top = max(scores.values())
        if scores["bo"] >= top - 1e-2 * abs(top):
            wins += 1
    assert wins >= (len(MEASURED_WORKLOADS) + 1) // 2, f"BO won only {wins}"


def test_exploration_ordering_bo_ge_nms():
    """Table 2: BO coverage ~100%, >= NMS coverage on average."""
    w = MEASURED_WORKLOADS[0]
    cov = {}
    for algo in ALGOS:
        h = _run(w, algo, seed=0)
        fr = h.sampled_range_fraction()
        cov[algo] = np.mean(list(fr.values()))
    assert cov["bo"] >= 0.9
    assert cov["bo"] >= cov["nms"] - 0.05
    assert cov["bo"] >= cov["ga"] - 0.05
