"""End-to-end training: loss decreases; failure -> restore -> identical
stream; microbatching equivalence."""
import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.models.params import split_params
from repro.models.runtime import Runtime
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.runtime.fault_tolerance import FailureInjector
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig

pytestmark = pytest.mark.slow  # real training loops


def test_loss_decreases_dense():
    cfg = get_config("qwen2-0.5b").reduced()
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=2e-3, warmup_steps=5, total_steps=60),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8),
        TrainerConfig(steps=60, log_every=0),
        rt=Runtime(compute_dtype="f32"),
    )
    log = trainer.run()
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first - 0.5, (first, last)


def test_failure_recovery_resumes_stream(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    common = dict(
        opt_cfg=OptimizerConfig(learning_rate=1e-3, warmup_steps=5,
                                total_steps=40),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=4),
    )
    t_plain = Trainer(cfg, common["opt_cfg"], common["data_cfg"],
                      TrainerConfig(steps=30, log_every=0),
                      rt=Runtime(compute_dtype="f32"))
    log_plain = t_plain.run()

    t_fail = Trainer(cfg, common["opt_cfg"], common["data_cfg"],
                     TrainerConfig(steps=30, log_every=0,
                                   checkpoint_dir=str(tmp_path / "ck"),
                                   checkpoint_every=10),
                     rt=Runtime(compute_dtype="f32"),
                     failure_injector=FailureInjector(at_steps=[15]))
    log_fail = t_fail.run()
    assert any("failure" in e for e in t_fail.events)
    assert any("restored" in e for e in t_fail.events)
    # training reached the same step count and a comparable loss
    assert log_fail[-1]["step"] == log_plain[-1]["step"] == 29
    assert abs(log_fail[-1]["loss"] - log_plain[-1]["loss"]) < 0.2


def test_microbatch_grad_equivalence():
    """k microbatches must produce (near-)identical updates to k=1."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    rt = Runtime(compute_dtype="f32")
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    opt_cfg = OptimizerConfig(warmup_steps=0)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
    }
    outs = {}
    for k in (1, 2, 4):
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(model, opt_cfg, rt, microbatches=k))
        p2, _, m = step(params, opt, batch)
        outs[k] = (p2, float(m["loss"]))
    for k in (2, 4):
        assert abs(outs[k][1] - outs[1][1]) < 1e-4
        for a, b in zip(jax.tree_util.tree_leaves(outs[k][0]),
                        jax.tree_util.tree_leaves(outs[1][0])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=5e-3)


def test_remat_modes_agree():
    """Remat changes memory, not math: losses/updates must match."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    opt_cfg = OptimizerConfig(warmup_steps=0)
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
    }
    losses = {}
    for remat in ("none", "dots", "names", "full"):
        rt = Runtime(compute_dtype="f32", remat=remat)
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(model, opt_cfg, rt))
        _, _, metrics = step(params, opt, batch)
        losses[remat] = float(metrics["loss"])
    base = losses["none"]
    for remat, v in losses.items():
        assert abs(v - base) < 1e-4, losses
