"""Pallas flash/decode attention vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # Pallas kernel sweeps

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


SWEEP = [
    # B, Sq, Sk, H, K, dh, causal, window
    (1, 16, 16, 4, 4, 16, True, None),
    (2, 37, 37, 4, 2, 16, True, None),   # GQA + ragged padding
    (1, 64, 64, 8, 1, 32, True, None),   # MQA
    (1, 50, 50, 4, 4, 16, True, 9),      # sliding window
    (2, 13, 29, 4, 1, 8, False, None),   # cross-attention shape
    (1, 128, 128, 2, 2, 64, True, None),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SWEEP, ids=[str(c) for c in SWEEP])
def test_flash_attention_matches_ref(rng, case, dtype):
    B, Sq, Sk, H, K, dh, causal, window = case
    q = _mk(rng, B, Sq, H, dh, dtype=dtype)
    k = _mk(rng, B, Sk, K, dh, dtype=dtype)
    v = _mk(rng, B, Sk, K, dh, dtype=dtype)
    out_ref = ops.attention(q, k, v, causal=causal, window=window, impl="ref")
    out_pal = ops.attention(q, k, v, causal=causal, window=window,
                            impl="pallas", block_q=16, block_kv=16)
    np.testing.assert_allclose(
        np.asarray(out_pal, np.float32), np.asarray(out_ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("case", SWEEP, ids=[str(c) for c in SWEEP])
def test_chunked_attention_matches_ref(rng, case):
    B, Sq, Sk, H, K, dh, causal, window = case
    q, k, v = (_mk(rng, B, Sq, H, dh), _mk(rng, B, Sk, K, dh),
               _mk(rng, B, Sk, K, dh))
    out_ref = ops.attention(q, k, v, causal=causal, window=window, impl="ref")
    for unroll in (False, True):
        out_ch = ref.attention_chunked_ref(q, k, v, causal=causal,
                                           window=window, block_q=16,
                                           unroll=unroll)
        np.testing.assert_allclose(np.asarray(out_ch), np.asarray(out_ref),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(rng, dtype):
    B, H, K, dh, Smax = 3, 8, 2, 16, 50
    q = _mk(rng, B, H, dh, dtype=dtype)
    k = _mk(rng, B, Smax, K, dh, dtype=dtype)
    v = _mk(rng, B, Smax, K, dh, dtype=dtype)
    lengths = jnp.array([50, 17, 1], jnp.int32)
    out_ref = ops.decode_attention(q, k, v, lengths, impl="ref")
    out_pal = ops.decode_attention(q, k, v, lengths, impl="pallas", block_kv=16)
    np.testing.assert_allclose(
        np.asarray(out_pal, np.float32), np.asarray(out_ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_attention_pallas_grads_flow(rng):
    """custom_vjp pairing: pallas forward, ref-recompute backward."""
    B, S, H, K, dh = 1, 32, 4, 2, 16
    q, k, v = _mk(rng, B, S, H, dh), _mk(rng, B, S, K, dh), _mk(rng, B, S, K, dh)

    def loss_pal(q, k, v):
        return ops.attention(q, k, v, impl="pallas", block_q=16,
                             block_kv=16).sum()

    def loss_ref(q, k, v):
        return ops.attention(q, k, v, impl="ref").sum()

    g_pal = jax.grad(loss_pal, (0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g_pal, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)


def test_mla_shaped_dv_differs_from_dh(rng):
    """MLA: qk head dim 96, v head dim 64 — all impls must handle it."""
    q, k = _mk(rng, 2, 33, 4, 24), _mk(rng, 2, 33, 4, 24)
    v = _mk(rng, 2, 33, 4, 16)
    o_ref = ops.attention(q, k, v, impl="ref")
    assert o_ref.shape == (2, 33, 4, 16)
    for kwargs in ({"impl": "chunked", "block_q": 16},
                   {"impl": "chunked", "block_q": 16, "unroll": True,
                    "prune": True},
                   {"impl": "pallas", "block_q": 16, "block_kv": 16}):
        o = ops.attention(q, k, v, **kwargs)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


def test_pruned_unrolled_matches_masked(rng):
    q, k, v = _mk(rng, 1, 50, 4, 16), _mk(rng, 1, 50, 2, 16), _mk(rng, 1, 50, 2, 16)
    for win in (None, 9):
        o1 = ops.attention(q, k, v, causal=True, window=win, impl="ref")
        o2 = ops.attention(q, k, v, causal=True, window=win, impl="chunked",
                           block_q=16, unroll=True, prune=True)
        np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=2e-5)


def test_fully_masked_rows_are_zero(rng):
    """Window smaller than gap -> fully masked rows must not NaN."""
    q = _mk(rng, 1, 8, 2, 8)
    k = _mk(rng, 1, 8, 2, 8)
    v = _mk(rng, 1, 8, 2, 8)
    out = ops.attention(q, k, v, causal=False, window=1, impl="pallas",
                        block_q=4, block_kv=4)
    assert not bool(jnp.isnan(out).any())
