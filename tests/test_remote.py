"""Remote measurement workers: wire protocol framing, the
RemoteWorkerPool executor backend (submit/next_completed/preempt over
TCP), worker-death reinjection with exactly-once recording, per-eval
timeouts across the wire, heartbeat stall detection, and end-to-end
Tuner runs (async loop and multi-fidelity) against an in-process worker
fleet."""
import json
import math
import socket
import struct
import threading
import time

import pytest

from repro.core import IntDim, SearchSpace, Tuner, TunerConfig
from repro.launch.worker import resolve_objective
from repro.tuning.cache import JsonCacheStore
from repro.tuning.executor import EvaluationExecutor
from repro.tuning.objective import CountingEvaluator, Evaluator
from repro.tuning.remote import (
    PROTOCOL_VERSION,
    RemoteWorkerPool,
    WorkerServer,
    parse_address,
    recv_msg,
    send_msg,
)


def small_space() -> SearchSpace:
    return SearchSpace([IntDim("a", 0, 20), IntDim("b", 0, 9)])


def value_of(p) -> float:
    return float(p["a"] * 10 + p["b"])


class SleepyObjective(Evaluator):
    """Deterministic value, configurable sleep, thread-safe call log."""

    def __init__(self, seconds=0.0):
        self.seconds = seconds
        self.calls = []
        self._lock = threading.Lock()

    def __call__(self, p, fidelity=None):
        time.sleep(self.seconds)
        with self._lock:
            self.calls.append((p["a"], p["b"]))
        return value_of(p), {"src": "worker"}


# ---------------------------------------------------------------------------
# framing + address/objective resolution
# ---------------------------------------------------------------------------

def test_framing_roundtrip_including_nonfinite():
    a, b = socket.socketpair()
    try:
        msgs = [
            {"type": "task", "id": 1, "point": {"a": 3}, "fidelity": None},
            {"type": "result", "id": 1, "value": -math.inf,
             "seconds": 0.25, "meta": {"error": "OOM", "nan": math.nan}},
        ]
        for m in msgs:
            send_msg(a, m)
        got1 = recv_msg(b)
        got2 = recv_msg(b)
        assert got1 == msgs[0]
        assert got2["value"] == -math.inf  # failed-config score survives
        assert math.isnan(got2["meta"]["nan"])
    finally:
        a.close()
        b.close()


def test_framing_peer_close_raises_connection_error():
    a, b = socket.socketpair()
    a.sendall(struct.pack(">I", 100) + b"short")  # truncated frame
    a.close()
    with pytest.raises(ConnectionError):
        recv_msg(b)
    b.close()


def test_framing_rejects_oversized_and_non_object_frames():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack(">I", 1 << 30))
        with pytest.raises(ValueError):
            recv_msg(b)
    finally:
        a.close()
        b.close()
    a, b = socket.socketpair()
    try:
        payload = json.dumps([1, 2, 3]).encode()
        a.sendall(struct.pack(">I", len(payload)) + payload)
        with pytest.raises(ValueError):
            recv_msg(b)
    finally:
        a.close()
        b.close()


def test_parse_address():
    assert parse_address("localhost:9123") == ("localhost", 9123)
    assert parse_address("::1:9123") == ("::1", 9123)  # v6: last colon splits
    with pytest.raises(ValueError):
        parse_address("no-port")
    with pytest.raises(ValueError):
        parse_address(":9123")


def _plain_objective(p):
    return float(p["a"])


def _factory():
    return SleepyObjective()


def test_resolve_objective_specs():
    fn = resolve_objective("tests.test_remote:_plain_objective")
    # identity can differ (the test module imports under two names), but
    # it must be the same function object semantically
    assert fn.__name__ == "_plain_objective" and fn({"a": 4}) == 4.0
    made = resolve_objective("tests.test_remote:_factory()")
    assert type(made).__name__ == "SleepyObjective"  # factory was called
    with pytest.raises(ValueError):
        resolve_objective("justamodule")


# ---------------------------------------------------------------------------
# pool + executor over a live in-process fleet
# ---------------------------------------------------------------------------

@pytest.fixture
def fleet():
    """Two workers (slots 1 + 2) serving SleepyObjective; yields
    (objective, [servers]); servers are torn down afterwards."""
    obj = SleepyObjective(seconds=0.01)
    servers = [WorkerServer(obj, slots=1, heartbeat_s=0.2).start(),
               WorkerServer(obj, slots=2, heartbeat_s=0.2).start()]
    yield obj, servers
    for s in servers:
        s.stop()


def test_remote_executor_roundtrip_and_memo(fleet):
    obj, servers = fleet
    space = small_space()
    ex = EvaluationExecutor(obj, space,
                            workers=[s.address for s in servers])
    assert ex.backend == "remote"
    assert ex.parallelism == 3  # fleet slot total: 1 + 2
    pts = [{"a": i, "b": i % 3} for i in range(6)]
    got = {tuple(sorted(p.point.items())): p.result()
           for p in ex.as_completed(ex.submit(pts))}
    assert len(got) == 6
    for r in got.values():
        assert r.value == value_of(r.point)
        assert r.meta["src"] == "worker"  # worker meta crossed the wire
    assert len(obj.calls) == 6
    # memo: a repeat submit resolves instantly, nothing re-measured
    again = ex.submit(pts)
    assert all(p.done() and p.result().meta.get("memoized") for p in again)
    assert len(obj.calls) == 6
    ex.close()


def test_remote_inflight_aliasing_shares_measurement():
    obj = SleepyObjective(seconds=0.15)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address])
    p = {"a": 5, "b": 1}
    first = ex.submit([p])
    second = ex.submit([p])  # same key while in flight: shares the future
    assert second[0].future is first[0].future
    done = list(ex.as_completed(first + second))
    assert len(done) == 2
    assert len(obj.calls) == 1  # one real measurement
    assert {d.result().value for d in done} == {value_of(p)}
    ex.close()
    server.stop()


def test_remote_preempt_queued_is_cancelled_and_unrecorded(tmp_path):
    obj = SleepyObjective(seconds=0.2)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    path = str(tmp_path / "memo.json")
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address],
                            cache_path=path)
    running, queued = ex.submit([{"a": 1, "b": 0}, {"a": 2, "b": 0}])
    time.sleep(0.05)  # let the dispatcher hand task 1 to the only slot
    verdict = ex.preempt(queued)
    assert verdict == "cancelled"
    assert queued.result().meta == {"preempted": True}
    done = ex.next_completed([running])
    assert done.result().value == value_of(running.point)
    ex.close()
    # the preempted point was never measured, never cached, not persisted
    assert (2, 0) not in obj.calls
    stored = JsonCacheStore(path).load()
    assert all(json.loads(k)[0] != 2 for k in stored)
    server.stop()


def test_remote_preempt_running_lets_it_finish():
    obj = SleepyObjective(seconds=0.15)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address])
    (pend,) = ex.submit([{"a": 7, "b": 2}])
    time.sleep(0.05)  # dispatched: the worker already started measuring
    assert ex.preempt(pend) == "running"
    done = ex.next_completed([pend])
    assert done is pend and done.result().value == value_of(pend.point)
    assert len(obj.calls) == 1  # paid-for measurement recorded exactly once
    ex.close()
    server.stop()


def test_remote_timeout_holds_across_the_wire(tmp_path):
    obj = SleepyObjective(seconds=0.6)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    path = str(tmp_path / "memo.json")
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address],
                            timeout=0.15, cache_path=path)
    (pend,) = ex.submit([{"a": 3, "b": 3}])
    time.sleep(0.05)  # ensure it was dispatched (not resolved inline)
    t0 = time.perf_counter()
    done = ex.next_completed([pend])
    waited = time.perf_counter() - t0
    assert done.result().value == -math.inf
    assert done.result().meta.get("timeout")
    assert waited < 0.5  # resolved at the deadline, not at worker pace
    ex.close()
    # a timeout verdict reflects this run's setting: never persisted
    assert JsonCacheStore(path).load() == {}
    server.stop()


def test_remote_worker_death_reinjects_not_fails():
    obj = SleepyObjective(seconds=0.08)
    s1 = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    s2 = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    ex = EvaluationExecutor(obj, small_space(),
                            workers=[s1.address, s2.address])
    pend = ex.submit([{"a": i, "b": 0} for i in range(8)])
    threading.Timer(0.1, s2.stop).start()  # a host dies mid-run
    results = [p.result() for p in ex.as_completed(pend)]
    assert len(results) == 8
    # every point got a real measurement — a disconnect is a fleet
    # property, never recorded as a configuration failure
    for r in results:
        assert r.value == value_of(r.point), r.point
    # exactly-once: no point was recorded twice even though reinjection
    # may re-measure one the dead worker had started
    keys = [tuple(sorted(r.point.items())) for r in results]
    assert len(keys) == len(set(keys))
    assert ex._pool.alive_workers() == 1
    ex.close()
    s1.stop()


def test_remote_whole_fleet_down_fails_loudly():
    obj = SleepyObjective(seconds=0.3)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.2).start()
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address])
    pend = ex.submit([{"a": 1, "b": 1}, {"a": 2, "b": 2}])
    time.sleep(0.05)
    server.stop()  # no survivors: the run cannot proceed
    with pytest.raises(ConnectionError):
        for _ in ex.as_completed(pend):
            pass
    ex.close()


def test_remote_objective_exception_scores_minus_inf():
    def boom(p):
        raise RuntimeError("OOM")

    server = WorkerServer(boom, slots=1, heartbeat_s=0.2).start()
    ex = EvaluationExecutor(boom, small_space(), workers=[server.address])
    (pend,) = ex.submit([{"a": 1, "b": 0}])
    r = ex.next_completed([pend]).result()
    assert r.value == -math.inf
    assert "OOM" in r.meta["error"]  # failure crossed as a result,
    ex.close()                       # not as a protocol error
    server.stop()


def test_remote_unreachable_worker_fails_fast():
    with pytest.raises(ConnectionError):
        RemoteWorkerPool(["127.0.0.1:1"], connect_timeout=0.3)


def test_remote_submit_after_fleet_death_raises_not_hangs():
    """A task enqueued with no live worker would never resolve; submit
    must refuse loudly instead of letting the driver wait forever."""
    obj = SleepyObjective(seconds=0.01)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.1).start()
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address])
    server.stop()
    deadline = time.time() + 5
    while ex._pool.alive_workers() and time.time() < deadline:
        time.sleep(0.01)  # wait for the pool to notice the EOF
    with pytest.raises(ConnectionError):
        ex.submit([{"a": 1, "b": 1}])
    ex.close()


def test_remote_capacity_shrinks_when_a_worker_dies():
    obj = SleepyObjective(seconds=0.01)
    s1 = WorkerServer(obj, slots=2, heartbeat_s=0.1).start()
    s2 = WorkerServer(obj, slots=2, heartbeat_s=0.1).start()
    ex = EvaluationExecutor(obj, small_space(),
                            workers=[s1.address, s2.address])
    assert ex.parallelism == 4
    s2.stop()
    deadline = time.time() + 5
    while ex.parallelism != 2 and time.time() < deadline:
        time.sleep(0.01)
    # the driver's in-flight window follows the live fleet, so dead
    # slots are not advertised and tasks don't starve in the queue
    assert ex.parallelism == 2
    ex.close()
    s1.stop()


def test_stray_connection_does_not_wedge_worker():
    """Sessions are serial, so a connection that never says hello (port
    scan, health probe) must be dropped by the handshake timeout and the
    real tuner served afterwards."""
    obj = SleepyObjective(seconds=0.01)
    server = WorkerServer(obj, slots=1, heartbeat_s=0.2)
    server.handshake_timeout_s = 0.3  # fast test; default is 10s
    server.start()
    stray = socket.create_connection((server.host, server.port))
    time.sleep(0.05)  # the worker is now blocked reading stray's hello
    ex = EvaluationExecutor(obj, small_space(), workers=[server.address])
    (pend,) = ex.submit([{"a": 4, "b": 4}])
    assert ex.next_completed([pend]).result().value == value_of(pend.point)
    ex.close()
    stray.close()
    server.stop()


def test_worker_survives_tuner_restart(fleet):
    obj, servers = fleet
    space = small_space()
    for round_ in range(2):
        ex = EvaluationExecutor(obj, space, workers=[servers[0].address])
        (pend,) = ex.submit([{"a": round_, "b": round_}])
        assert ex.next_completed([pend]).result().value == value_of(
            pend.point)
        ex.close()
    assert servers[0].sessions_served == 2


def test_heartbeat_stall_marks_worker_dead():
    """A worker that registers then goes silent (hung host, not a closed
    socket) is detected via missed heartbeats and its task reinjected."""
    lsock = socket.create_server(("127.0.0.1", 0))
    frozen_port = lsock.getsockname()[1]

    def frozen_worker():
        conn, _ = lsock.accept()
        recv_msg(conn)  # hello
        send_msg(conn, {"type": "register", "protocol": PROTOCOL_VERSION,
                        "slots": 1, "heartbeat_s": 0.05})
        recv_msg(conn)  # accept one task, then never respond, never beat
        time.sleep(5.0)

    threading.Thread(target=frozen_worker, daemon=True).start()
    obj = SleepyObjective(seconds=0.02)
    healthy = WorkerServer(obj, slots=1, heartbeat_s=0.05).start()
    ex = EvaluationExecutor(
        obj, small_space(),
        workers=[f"127.0.0.1:{frozen_port}", healthy.address])
    # 2 tasks: one lands on the frozen worker, one on the healthy one
    pend = ex.submit([{"a": 1, "b": 1}, {"a": 2, "b": 2}])
    results = [p.result() for p in ex.as_completed(pend)]
    assert sorted(r.value for r in results) == sorted(
        value_of(p.point) for p in pend)
    assert ex._pool.alive_workers() == 1
    ex.close()
    healthy.stop()
    lsock.close()


# ---------------------------------------------------------------------------
# end to end through the Tuner
# ---------------------------------------------------------------------------

def test_tuner_remote_backend_end_to_end(tmp_path):
    obj = SleepyObjective(seconds=0.005)
    servers = [WorkerServer(obj, slots=2, heartbeat_s=0.2).start()
               for _ in range(2)]
    path = str(tmp_path / "memo.json")
    t = Tuner(obj, small_space(),
              TunerConfig(algorithm="random", budget=12, seed=0,
                          verbose=False, memo_cache_path=path,
                          workers=[s.address for s in servers]))
    assert t.executor.backend == "remote"
    assert t.executor.parallelism == 4
    h = t.run()
    t.close()
    assert len(h) == 12
    assert all(e.value == value_of(e.point) for e in h.evals)

    # the memo was written BY THE TUNER HOST (workers share no
    # filesystem with the store) and is honored across backends: a
    # second run on the local thread backend re-evaluates nothing
    counting = CountingEvaluator(lambda p: value_of(p))
    t2 = Tuner(counting, small_space(),
               TunerConfig(algorithm="random", budget=12, seed=0,
                           verbose=False, parallelism=2,
                           memo_cache_path=path))
    h2 = t2.run()
    t2.close()
    assert counting.calls == 0
    assert sorted(e.value for e in h2.evals) == sorted(
        e.value for e in h.evals)
    for s in servers:
        s.stop()


def test_tuner_remote_multi_fidelity_composes():
    class FidObjective(Evaluator):
        supports_fidelity = True

        def __init__(self):
            self.log = []
            self._lock = threading.Lock()

        def __call__(self, p, fidelity=None):
            f = 1.0 if fidelity is None else float(fidelity)
            time.sleep(0.01 * f)
            v = value_of(p) + (1.0 - f) * ((p["a"] * 7) % 5 - 2)
            with self._lock:
                self.log.append((p["a"], p["b"], round(f, 9)))
            return v, {"cost_seconds": 0.01 * f}

    obj = FidObjective()
    servers = [WorkerServer(obj, slots=2, heartbeat_s=0.2).start()
               for _ in range(2)]
    t = Tuner(obj, small_space(),
              TunerConfig(algorithm="random", budget=6, seed=0,
                          verbose=False, multi_fidelity=True,
                          workers=[s.address for s in servers]))
    h = t.run()
    stats = t.rung_scheduler.stats()
    t.close()
    # rungs actually ran at partial fidelity over the wire
    assert any(e.fidelity < 1.0 for e in h.evals)
    assert stats[0]["completed"] > 0
    # exactly-once: every real worker-side measurement is recorded once
    measured = [e for e in h.evals if not e.meta.get("memoized")]
    assert len(measured) == len(obj.log)
    keys = [(e.point["a"], e.point["b"], round(e.fidelity, 9))
            for e in measured]
    assert len(keys) == len(set(keys))
    for s in servers:
        s.stop()
