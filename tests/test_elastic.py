"""Elastic measurement fleets: mid-run worker join/leave over the
pool's always-open join socket, speculative straggler re-execution with
exactly-once recording, hardware-fingerprint partitioning (strict vs
normalize homogeneity), per-worker heartbeat stall windows, and the
multi-fidelity drain surviving a mid-drain worker kill."""
import socket
import threading
import time

import pytest

from repro.core import IntDim, SearchSpace, Tuner, TunerConfig
from repro.tuning.corpus import TuningCorpus
from repro.tuning.executor import EvaluationExecutor
from repro.tuning.fidelity import CompletionStats, StreamingQuantiles
from repro.tuning.objective import Evaluator
from repro.tuning.remote import (
    UNKNOWN_FINGERPRINT,
    UNKNOWN_PARTITION,
    FleetOptions,
    RemoteWorkerPool,
    WorkerServer,
    fingerprint_id,
    recv_msg,
    send_msg,
)


def small_space() -> SearchSpace:
    return SearchSpace([IntDim("a", 0, 20), IntDim("b", 0, 9)])


def value_of(p) -> float:
    return float(p["a"] * 10 + p["b"])


def local(pool: RemoteWorkerPool) -> str:
    """The pool's join address, dialable from this host."""
    port = pool.join_address.rsplit(":", 1)[1]
    return f"127.0.0.1:{port}"


class GatedObjective(Evaluator):
    """Deterministic value; selected points block on an event (one
    instance per in-process worker, so a gate stalls exactly one host)."""

    def __init__(self):
        self.gates = {}
        self.calls = []
        self._lock = threading.Lock()

    def gate(self, a, b) -> threading.Event:
        ev = threading.Event()
        self.gates[(a, b)] = ev
        return ev

    def __call__(self, p, fidelity=None):
        key = (p["a"], p["b"])
        with self._lock:
            self.calls.append(key)
        ev = self.gates.get(key)
        if ev is not None:
            assert ev.wait(20.0), f"test gate for {key} never released"
        # declared cost: deterministic, independent of which worker ran it
        return value_of(p), {"src": "worker", "cost_seconds": 0.01}


def wait_until(cond, timeout=5.0, msg="condition"):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {msg}")


# ---------------------------------------------------------------------------
# streaming quantiles / completion stats (tuning/fidelity.py)
# ---------------------------------------------------------------------------

def test_streaming_quantiles_nearest_rank():
    q = StreamingQuantiles()
    for v in [5.0, 1.0, 3.0, 2.0, 4.0]:
        q.add(v)
    assert q.n == 5
    assert q.p50() == 3.0
    assert q.p95() == 5.0
    assert q.quantile(0.0) == 1.0


def test_streaming_quantiles_ignores_garbage_and_caps_window():
    q = StreamingQuantiles(max_samples=8)
    q.add(float("nan"))
    q.add(float("inf"))
    q.add(-1.0)
    assert q.n == 0 and q.p95() is None
    for v in [100.0, 200.0] + [float(i) for i in range(1, 9)]:
        q.add(v)
    # ring buffer: the samples from a departed slow host age out, so
    # only the 8 most recent observations shape the quantiles
    assert q.n == 10
    assert q.p95() == 8.0


def test_completion_stats_buckets_by_fidelity():
    cs = CompletionStats()
    for s in [1.0, 2.0, 3.0]:
        cs.record(None, s)       # None keys as full fidelity
    cs.record(1.0, 4.0)          # ... the same bucket as None
    cs.record(0.33, 10.0)
    assert cs.observations(None) == 4
    assert cs.observations(1.0) == 4
    assert cs.observations(0.33) == 1
    assert cs.p95(None) == 4.0
    assert cs.p95(0.33) == 10.0
    assert cs.p95(0.5) is None   # never-observed rung: no threshold
    snap = {row["fidelity"]: row for row in cs.snapshot()}
    assert snap[1.0]["n"] == 4 and snap[0.33]["n"] == 1


def test_fingerprint_id_is_stable_and_order_insensitive():
    a = fingerprint_id({"backend": "cpu", "cores": 8})
    b = fingerprint_id({"cores": 8, "backend": "cpu"})
    assert a == b and len(a) == 12
    assert fingerprint_id(None) == fingerprint_id(UNKNOWN_FINGERPRINT)
    assert fingerprint_id(UNKNOWN_FINGERPRINT) == UNKNOWN_PARTITION
    assert fingerprint_id({"backend": "gpu"}) != a


# ---------------------------------------------------------------------------
# mid-run join / clean leave
# ---------------------------------------------------------------------------

def test_mid_run_join_grows_live_parallelism():
    obj1, obj2 = GatedObjective(), GatedObjective()
    s1 = WorkerServer(obj1, slots=1, heartbeat_s=0.1).start()
    ex = EvaluationExecutor(obj1, small_space(), workers=[s1.address],
                            fleet=FleetOptions(speculation=False))
    assert ex.parallelism == 1
    s2 = WorkerServer(obj2, slots=2, heartbeat_s=0.1)
    s2.start_join(local(ex.remote_pool))
    wait_until(lambda: ex.parallelism == 3, msg="joiner registering")
    pts = [{"a": i, "b": 0} for i in range(6)]
    results = [p.result() for p in ex.as_completed(ex.submit(pts))]
    assert sorted(r.value for r in results) == sorted(
        value_of(p) for p in pts)
    # the joiner actually measured (capacity was real, not cosmetic)
    assert obj2.calls
    rows = {w["address"]: w for w in ex.remote_pool.fleet_health()}
    joined = [w for w in rows.values() if w["origin"] == "join"]
    assert len(joined) == 1 and joined[0]["slots"] == 2
    for w in rows.values():  # elastic health fields are always present
        assert isinstance(w["fingerprint"], dict)
        assert w["partition"] and w["joined_at"] > 0
        assert "inflight_age_max" in w and "speculating" in w
    ex.close()
    s1.stop()
    s2.stop()


def test_empty_elastic_start_queues_until_first_join():
    obj = GatedObjective()
    ex = EvaluationExecutor(obj, small_space(), backend="remote",
                            fleet=FleetOptions(speculation=False))
    assert ex.remote_pool.join_address is not None
    pend = ex.submit([{"a": 4, "b": 2}])  # queues: no worker yet
    w = WorkerServer(obj, slots=1, heartbeat_s=0.1)
    w.start_join(local(ex.remote_pool))
    done = ex.next_completed(pend)
    assert done.result().value == value_of(done.point)
    ex.close()
    w.stop()


def test_remote_without_workers_or_join_socket_still_fails():
    with pytest.raises(ValueError, match="backend='remote'"):
        EvaluationExecutor(GatedObjective(), small_space(), backend="remote",
                           fleet=FleetOptions(listen_port=None))


def test_clean_leave_drains_inflight_and_shrinks_capacity():
    obj1, obj2 = GatedObjective(), GatedObjective()
    hold = obj1.gate(9, 9)
    s1 = WorkerServer(obj1, slots=1, heartbeat_s=0.1).start()
    s2 = WorkerServer(obj2, slots=1, heartbeat_s=0.1).start()
    ex = EvaluationExecutor(obj1, small_space(),
                            workers=[s1.address, s2.address],
                            fleet=FleetOptions(speculation=False))
    pool = ex.remote_pool
    (pend,) = ex.submit([{"a": 9, "b": 9}])  # dispatches to s1 (first free)
    wait_until(lambda: (9, 9) in obj1.calls, msg="dispatch to s1")
    assert s1.request_leave()
    # draining: capacity excludes the leaver immediately, but its
    # in-flight measurement is NOT abandoned
    wait_until(lambda: ex.parallelism == 1, msg="drain to start")
    assert not pend.done()
    hold.set()
    done = ex.next_completed([pend])
    assert done.result().value == value_of({"a": 9, "b": 9})
    wait_until(lambda: pool.clean_leaves == 1, msg="clean leave")
    assert pool.alive_workers() == 1
    assert obj1.calls == [(9, 9)] and obj2.calls == []  # measured once
    # the remaining worker keeps serving new work
    (p2,) = ex.submit([{"a": 1, "b": 1}])
    assert ex.next_completed([p2]).result().value == 11.0
    ex.close()
    s1.stop()
    s2.stop()


def test_leave_with_empty_inflight_departs_immediately():
    obj = GatedObjective()
    s1 = WorkerServer(obj, slots=1, heartbeat_s=0.1).start()
    s2 = WorkerServer(obj, slots=1, heartbeat_s=0.1).start()
    ex = EvaluationExecutor(obj, small_space(),
                            workers=[s1.address, s2.address],
                            fleet=FleetOptions(speculation=False))
    pool = ex.remote_pool
    assert s2.request_leave()
    wait_until(lambda: pool.clean_leaves == 1, msg="idle leave")
    assert ex.parallelism == 1
    ex.close()
    s1.stop()
    s2.stop()


# ---------------------------------------------------------------------------
# speculative straggler re-execution: exactly-once under both orderings
# ---------------------------------------------------------------------------

def _speculation_fleet(tmp_path):
    """(slow_obj, fast_obj, s_slow, s_fast, executor, corpus): 1-slot
    straggler + 1-slot healthy worker with aggressive speculation."""
    slow_obj, fast_obj = GatedObjective(), GatedObjective()
    s_slow = WorkerServer(slow_obj, slots=1, heartbeat_s=0.1).start()
    s_fast = WorkerServer(fast_obj, slots=1, heartbeat_s=0.1).start()
    corpus = TuningCorpus(tmp_path / "corpus.json", job_id="spec")
    ex = EvaluationExecutor(
        slow_obj, small_space(),
        workers=[s_slow.address, s_fast.address],
        cache_path=str(tmp_path / "memo.json"), corpus=corpus,
        fleet=FleetOptions(speculation=True, speculation_factor=2.0,
                           min_observations=3))
    return slow_obj, fast_obj, s_slow, s_fast, ex, corpus


def _warmup(ex, n=4):
    """Seed the completion stats so the p95 threshold is trusted."""
    pts = [{"a": i, "b": 1} for i in range(n)]
    results = [p.result() for p in ex.as_completed(ex.submit(pts))]
    assert all(r.value == value_of(r.point) for r in results)
    return n


def test_speculation_duplicate_wins_loser_discarded(tmp_path):
    slow_obj, fast_obj, s_slow, s_fast, ex, corpus = \
        _speculation_fleet(tmp_path)
    pool = ex.remote_pool
    n_warm = _warmup(ex)
    hold = slow_obj.gate(9, 9)  # stalls ONLY on the slow worker
    # both workers idle -> the dispatcher picks the first (slow) one
    (pend,) = ex.submit([{"a": 9, "b": 9}])
    wait_until(lambda: (9, 9) in slow_obj.calls, msg="dispatch to straggler")
    # the monitor notices the straggler and duplicates it onto the fast
    # worker, which resolves it: the driver is unblocked by speculation
    done = ex.next_completed([pend])
    assert done.result().value == value_of({"a": 9, "b": 9})
    assert (9, 9) in fast_obj.calls
    assert pool.speculations == 1 and pool.speculation_wins == 1
    # now the straggler finishes: its result is a loser, discarded
    hold.set()
    wait_until(lambda: pool.losers_discarded == 1, msg="loser discard")
    ex.close()
    # exactly-once everywhere: one memo entry, one corpus record, and
    # the history-facing future resolved a single time (pend.done())
    recs = TuningCorpus(tmp_path / "corpus.json", job_id="x").records()
    keyed = [tuple(sorted(r["point"].items())) for r in recs]
    assert len(keyed) == len(set(keyed)) == n_warm + 1
    s_slow.stop()
    s_fast.stop()


def test_speculation_original_wins_duplicate_discarded(tmp_path):
    slow_obj, fast_obj, s_slow, s_fast, ex, corpus = \
        _speculation_fleet(tmp_path)
    pool = ex.remote_pool
    n_warm = _warmup(ex)
    hold_orig = slow_obj.gate(8, 8)
    hold_dup = fast_obj.gate(8, 8)  # the duplicate stalls too
    (pend,) = ex.submit([{"a": 8, "b": 8}])
    wait_until(lambda: (8, 8) in slow_obj.calls, msg="dispatch to straggler")
    wait_until(lambda: pool.speculations == 1, msg="duplicate dispatch")
    assert pool.speculating == 1  # both copies live right now
    hold_orig.set()  # the ORIGINAL finishes first this time
    done = ex.next_completed([pend])
    assert done.result().value == value_of({"a": 8, "b": 8})
    assert pool.speculation_wins == 0  # the straggler finished after all
    hold_dup.set()
    wait_until(lambda: pool.losers_discarded == 1, msg="duplicate discard")
    assert pool.speculating == 0
    ex.close()
    recs = TuningCorpus(tmp_path / "corpus.json", job_id="x").records()
    keyed = [tuple(sorted(r["point"].items())) for r in recs]
    assert len(keyed) == len(set(keyed)) == n_warm + 1
    s_slow.stop()
    s_fast.stop()


def test_killing_the_speculating_worker_loses_nothing(tmp_path):
    """SIGKILL-shaped death of the worker holding the duplicate: the
    original copy still resolves the task; 0 lost, 0 double-recorded."""
    slow_obj, fast_obj, s_slow, s_fast, ex, corpus = \
        _speculation_fleet(tmp_path)
    pool = ex.remote_pool
    n_warm = _warmup(ex)
    hold_orig = slow_obj.gate(7, 7)
    fast_obj.gate(7, 7)  # duplicate blocks forever (its host dies)
    (pend,) = ex.submit([{"a": 7, "b": 7}])
    wait_until(lambda: (7, 7) in slow_obj.calls, msg="dispatch to straggler")
    wait_until(lambda: pool.speculations == 1, msg="duplicate dispatch")
    s_fast.stop()  # hard death of the speculating worker
    wait_until(lambda: pool.alive_workers() == 1, msg="death detection")
    hold_orig.set()
    done = ex.next_completed([pend])
    assert done.result().value == value_of({"a": 7, "b": 7})
    ex.close()
    recs = TuningCorpus(tmp_path / "corpus.json", job_id="x").records()
    keyed = [tuple(sorted(r["point"].items())) for r in recs]
    assert len(keyed) == len(set(keyed)) == n_warm + 1
    s_slow.stop()


def test_history_identical_with_and_without_speculation(tmp_path):
    """Speculation must be invisible in the recorded trace: the same
    deterministic objective tuned with speculation on (and firing) vs
    off yields identical (point, value, cost, fidelity) observations."""

    def run(spec: bool, straggle: bool):
        obj_a, obj_b = GatedObjective(), GatedObjective()
        hold = obj_a.gate(2, 1) if straggle else None
        if straggle:  # release the straggler once its duplicate won
            threading.Timer(2.0, hold.set).start()
        sa = WorkerServer(obj_a, slots=1, heartbeat_s=0.1).start()
        sb = WorkerServer(obj_b, slots=1, heartbeat_s=0.1).start()
        tc = TunerConfig(algorithm="random", budget=8, seed=7,
                         workers=[sa.address, sb.address])
        tc.executor.speculation = spec
        tc.executor.speculation_factor = 2.0
        tc.executor.speculation_min_observations = 3
        tuner = Tuner(obj_a, small_space(), tc)
        hist = tuner.run()
        tuner.close()
        if hold is not None:
            hold.set()
        sa.stop()
        sb.stop()
        return sorted((tuple(sorted(e.point.items())), e.value,
                       e.cost_seconds, e.fidelity) for e in hist.evals)

    baseline = run(spec=False, straggle=False)
    with_spec = run(spec=True, straggle=True)
    assert with_spec == baseline


# ---------------------------------------------------------------------------
# hardware-aware scheduling: strict pinning vs normalize calibration
# ---------------------------------------------------------------------------

def test_strict_homogeneity_refuses_mixed_static_fleet():
    obj = GatedObjective()
    s1 = WorkerServer(obj, slots=1, heartbeat_s=0.1,
                      fingerprint={"kind": "A"}).start()
    s2 = WorkerServer(obj, slots=1, heartbeat_s=0.1,
                      fingerprint={"kind": "B"}).start()
    with pytest.raises(ConnectionError, match="strict homogeneity"):
        RemoteWorkerPool([s1.address, s2.address])
    s1.stop()
    s2.stop()


def test_strict_homogeneity_rejects_mismatched_joiner():
    obj = GatedObjective()
    s1 = WorkerServer(obj, slots=1, heartbeat_s=0.1,
                      fingerprint={"kind": "A"}).start()
    pool = RemoteWorkerPool([s1.address])
    alien = WorkerServer(obj, slots=1, heartbeat_s=0.1,
                         fingerprint={"kind": "B"})
    alien.start_join(local(pool))
    wait_until(lambda: pool.rejected_joins == 1, msg="join rejection")
    assert pool.parallelism == 1  # the run continues on its partition
    twin = WorkerServer(obj, slots=1, heartbeat_s=0.1,
                        fingerprint={"kind": "A"})
    twin.start_join(local(pool))
    wait_until(lambda: pool.parallelism == 2, msg="matching join")
    pool.shutdown()
    s1.stop()
    alien.stop()
    twin.stop()


def test_unknown_fingerprint_admissible_under_strict():
    """A v1 / pre-elastic daemon reports no fingerprint; 'did not
    report' must not be treated as different hardware."""
    obj = GatedObjective()
    lsock = socket.create_server(("127.0.0.1", 0))
    port = lsock.getsockname()[1]

    def v1_worker():
        conn, _ = lsock.accept()
        recv_msg(conn)  # hello
        send_msg(conn, {"type": "register", "protocol": 1, "slots": 1,
                        "heartbeat_s": 0.1})
        while True:  # beat until the pool says bye / closes
            try:
                send_msg(conn, {"type": "heartbeat"})
            except OSError:
                return
            time.sleep(0.05)

    threading.Thread(target=v1_worker, daemon=True).start()
    s1 = WorkerServer(obj, slots=1, heartbeat_s=0.1,
                      fingerprint={"kind": "A"}).start()
    pool = RemoteWorkerPool([f"127.0.0.1:{port}", s1.address])
    assert pool.parallelism == 2
    assert pool.fleet_stats()["partition"] == fingerprint_id({"kind": "A"})
    health = {w["address"]: w for w in pool.fleet_health()}
    assert health[f"127.0.0.1:{port}"]["partition"] == UNKNOWN_PARTITION
    pool.shutdown()
    s1.stop()
    lsock.close()


def test_normalize_admits_mixed_fleet_and_calibrates_cost(tmp_path):
    obj_a, obj_b = GatedObjective(), GatedObjective()
    sa = WorkerServer(obj_a, slots=1, heartbeat_s=0.1,
                      fingerprint={"kind": "A"}).start()
    sb = WorkerServer(obj_b, slots=1, heartbeat_s=0.1,
                      fingerprint={"kind": "B"}).start()
    ex = EvaluationExecutor(
        obj_a, small_space(), workers=[sa.address, sb.address],
        fleet=FleetOptions(speculation=False, homogeneity="normalize"))
    pool = ex.remote_pool
    fp_a, fp_b = fingerprint_id({"kind": "A"}), fingerprint_id({"kind": "B"})
    assert pool.parallelism == 2  # both admitted
    # one duplicate pair: partition B measured the same task 2x slower
    pool._calibration.observe(fp_a, 1.0, fp_b, 2.0)
    assert pool._calibration.factor(fp_b) == pytest.approx(0.5)
    (snap,) = pool.fleet_stats()["calibration"]
    assert snap == {"partition": fp_b, "reference": fp_a,
                    "ratio": 0.5, "n_pairs": 1}
    # a result measured on B is rescaled into reference seconds and
    # stamped with the factor; GatedObjective declares cost 0.01
    hold = obj_a.gate(9, 9)  # pin worker A so (5, 5) lands on B
    ex.submit([{"a": 9, "b": 9}])
    wait_until(lambda: (9, 9) in obj_a.calls, msg="A busy")
    (pend,) = ex.submit([{"a": 5, "b": 5}])
    done = ex.next_completed([pend])
    assert (5, 5) in obj_b.calls
    assert done.result().meta["cost_calibration"] == pytest.approx(0.5)
    assert done.result().cost_seconds == pytest.approx(0.005)
    hold.set()
    ex.close()
    sa.stop()
    sb.stop()


def test_calibration_ignores_pairs_off_reference():
    from repro.tuning.remote import _FleetCalibration

    cal = _FleetCalibration(reference="ref0")
    cal.observe("p1", 1.0, "p2", 2.0)   # no reference side: ignored
    cal.observe("ref0", 1.0, "ref0", 2.0)  # same partition: ignored
    cal.observe("ref0", 0.0, "p1", 2.0)    # non-positive: ignored
    assert cal.factor("p1") == 1.0 and cal.snapshot() == []
    cal.observe("ref0", 1.0, "p1", 4.0)
    cal.observe("p1", 1.0, "ref0", 1.0)  # order-insensitive
    assert cal.factor("p1") == pytest.approx((0.25 * 1.0) ** 0.5)


# ---------------------------------------------------------------------------
# per-worker heartbeat stall windows
# ---------------------------------------------------------------------------

def test_stall_window_derives_from_registered_heartbeat():
    obj = GatedObjective()
    s1 = WorkerServer(obj, slots=1, heartbeat_s=0.5).start()
    pool = RemoteWorkerPool([s1.address])
    assert pool._workers[0].heartbeat_timeout == pytest.approx(1.5)
    pool.shutdown()
    s1.stop()


def test_fleet_heartbeat_fallback_for_undeclared_workers():
    lsock = socket.create_server(("127.0.0.1", 0))
    port = lsock.getsockname()[1]

    def mute_worker():
        conn, _ = lsock.accept()
        recv_msg(conn)
        send_msg(conn, {"type": "register", "protocol": 1, "slots": 1})
        time.sleep(5.0)

    threading.Thread(target=mute_worker, daemon=True).start()
    pool = RemoteWorkerPool([f"127.0.0.1:{port}"],
                            fleet=FleetOptions(heartbeat_s=0.6))
    assert pool._workers[0].heartbeat_timeout == pytest.approx(1.8)
    pool.shutdown()
    lsock.close()


# ---------------------------------------------------------------------------
# stale-capacity regression: the MF drain survives a mid-drain kill
# ---------------------------------------------------------------------------

class FidelityObjective(Evaluator):
    """Fidelity-aware, deterministic, slow enough to be killed mid-run."""

    supports_fidelity = True

    def __init__(self, seconds=0.1):
        self.seconds = seconds

    def __call__(self, p, fidelity=None):
        time.sleep(self.seconds)
        return value_of(p), {"src": "worker", "fidelity": fidelity}


def test_multi_fidelity_drain_survives_worker_kill():
    obj1, obj2 = FidelityObjective(), FidelityObjective()
    s1 = WorkerServer(obj1, slots=2, heartbeat_s=0.1).start()
    s2 = WorkerServer(obj2, slots=2, heartbeat_s=0.1).start()
    tc = TunerConfig(algorithm="random", budget=6, seed=3,
                     multi_fidelity=True,
                     workers=[s1.address, s2.address])
    tc.executor.speculation = False
    tuner = Tuner(obj1, small_space(), tc)
    # a host dies while rungs are filling/draining: capacity must be
    # re-read live (the dead slots vanish) and its tasks reinjected —
    # the drain completes instead of deadlocking on phantom slots
    threading.Timer(0.25, s2.stop).start()
    hist = tuner.run()
    assert len(hist) > 0
    assert all(e.value == value_of(e.point) for e in hist.evals)
    wait_until(lambda: tuner.executor.parallelism == 2,
               msg="dead slots leaving the live capacity")
    tuner.close()
    s1.stop()


def test_slot_cap_governor_tracks_live_capacity():
    """The fair-share cap composes with live fleet capacity: capacity
    shrinking below the cap must shrink advertised parallelism too."""
    obj = GatedObjective()
    s1 = WorkerServer(obj, slots=2, heartbeat_s=0.1).start()
    s2 = WorkerServer(obj, slots=2, heartbeat_s=0.1).start()
    ex = EvaluationExecutor(obj, small_space(),
                            workers=[s1.address, s2.address],
                            fleet=FleetOptions(speculation=False))
    ex.slot_cap = 3
    assert ex.parallelism == 3  # min(cap, live 4)
    s2.stop()
    wait_until(lambda: ex.parallelism == 2, msg="cap re-reads live fleet")
    ex.close()
    s1.stop()
