"""Config plumbing: stray-point-key rejection, the shared remat enum,
and the every-remat-mode-lowers pin."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models.runtime import REMAT_MODES, Runtime
from repro.tuning.parameters import (
    BASELINE,
    BackendConfig,
    _REMAT,
    backend_space,
    config_from_point,
)


def test_stray_point_key_raises_with_names():
    with pytest.raises(ValueError) as e:
        config_from_point({"log2_dp": 2, "blok_q": 256})
    assert "blok_q" in str(e.value)


def test_allow_extra_escape_hatch():
    bc = config_from_point({"log2_dp": 2, "host_devices": 4},
                           allow_extra=("host_devices",))
    assert bc.log2_dp == 2
    # allow_extra whitelists exactly the named keys, nothing else
    with pytest.raises(ValueError, match="other"):
        config_from_point({"other": 1}, allow_extra=("host_devices",))


def test_backend_space_points_always_construct():
    # every dim the search space can emit is a real BackendConfig field
    import numpy as np

    from repro.configs import get_config
    from repro.core.space import SearchSpace

    rng = np.random.default_rng(0)
    for arch in ("qwen2-0.5b", "rwkv6-3b"):
        space = SearchSpace.from_dicts(backend_space(get_config(arch)))
        for point in space.sample(rng, 3):
            config_from_point(point)


def test_remat_enum_is_shared_and_validated():
    assert _REMAT is REMAT_MODES
    assert "names" in REMAT_MODES  # the mode the old docstring dropped
    with pytest.raises(ValueError, match="remat"):
        BackendConfig(remat="nmaes")
    with pytest.raises(ValueError, match="remat"):
        Runtime(remat="checkpoint_dots")
    for mode in REMAT_MODES:  # every valid choice constructs both
        assert BackendConfig(remat=mode).runtime().remat == mode


@pytest.mark.parametrize("mode", REMAT_MODES)
def test_every_remat_mode_lowers(mode):
    """The drift bug in reverse: a mode the tuner can emit must lower."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.models.params import split_params
    from repro.optim.optimizer import OptimizerConfig, adamw_init
    from repro.train.train_step import make_train_step

    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    rt = dataclasses.replace(Runtime(compute_dtype="f32"), remat=mode)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    opt_cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=1,
                              total_steps=2)
    opt_state = adamw_init(params, opt_cfg)
    batch = {"tokens": jnp.zeros((1, 16), jnp.int32),
             "targets": jnp.zeros((1, 16), jnp.int32)}
    step = make_train_step(model, opt_cfg, rt)
    jax.jit(step).lower(params, opt_state, batch)  # must not raise
