"""Batched ask/tell contract + parallel evaluation executor tests.

The golden fixture ``tests/golden/ask_tell_traces.json`` was captured
from the pre-batching single-point Tuner loop, so the ``parallelism=1``
tests pin bit-for-bit backward compatibility of the refactor.
"""
import json
import math
import pathlib
import time

import numpy as np
import pytest

from repro.core import ENGINES, Observation, SearchSpace, Tuner, TunerConfig
from repro.tuning.executor import EvalResult, EvaluationExecutor, MemoCache
from repro.tuning.objective import Evaluator, FunctionEvaluator, as_evaluator

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "ask_tell_traces.json")
    .read_text())

ALGOS = ["bo", "ga", "nms", "random", "exhaustive"]


def golden_space() -> SearchSpace:
    return SearchSpace.from_dicts(GOLDEN["space"])


def golden_objective(p):
    a, b, c = p["inter_op"], p["intra_op"], p["build"]
    return float(50.0 * pow(2.718281828, -((a - 11) / 5.0) ** 2)
                 + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)


# ---------------------------------------------------------------------------
# ask/tell contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_ask_batches_are_deterministic_and_deduped(algo):
    def batches(seed):
        space = golden_space()
        engine = ENGINES[algo](space, seed=seed)
        from repro.core import History
        h = History(space)
        out = []
        for _ in range(4):
            batch = engine.ask(5, h)
            assert batch, "ask returned an empty batch with grid remaining"
            keys = [space.key(p) for p in batch]
            assert len(set(keys)) == len(keys), f"duplicate points in batch: {batch}"
            out.append([dict(p) for p in batch])
            engine.tell([Observation(point=p, value=golden_objective(p))
                         for p in batch])
            for p in batch:
                h.add(p, golden_objective(p))
        return out
    assert batches(7) == batches(7)  # same seed -> same batches
    if algo != "exhaustive":  # the grid sweep is seed-independent by design
        assert batches(7) != batches(8)  # different seed explores differently


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("seed", [0, 3])
def test_parallelism_1_reproduces_seed_trace(algo, seed):
    """The refactored loop at parallelism=1 is bit-for-bit the old loop."""
    trace = GOLDEN["traces"][f"{algo}:{seed}"]
    t = Tuner(golden_objective, golden_space(),
              TunerConfig(algorithm=algo, budget=18, seed=seed,
                          verbose=False, parallelism=1))
    h = t.run()
    assert h.points() == trace["points"]
    assert [e.value for e in h.evals] == pytest.approx(trace["values"])


@pytest.mark.parametrize("algo", ["random", "exhaustive"])
def test_parallel_matches_sequential_best(algo):
    """Engines whose batch is just n sequential draws find the same best."""
    def run(par):
        t = Tuner(golden_objective, golden_space(),
                  TunerConfig(algorithm=algo, budget=24, seed=5,
                              verbose=False, parallelism=par))
        h = t.run()
        t.close()
        return h
    h1, h4 = run(1), run(4)
    assert len(h4) == 24
    assert h4.best().value == pytest.approx(h1.best().value)


@pytest.mark.parametrize("algo", ["bo", "ga", "nms", "random"])
def test_parallel_batches_reach_comparable_best(algo):
    """parallelism=4 spends the same budget and still finds a good optimum.

    (Exhaustive is excluded: 24 grid points in enumeration order make no
    attempt to find the optimum.)
    """
    t = Tuner(golden_objective, golden_space(),
              TunerConfig(algorithm=algo, budget=24, seed=0,
                          verbose=False, parallelism=4))
    h = t.run()
    t.close()
    assert len(h) == 24
    # global max of the objective is ~68.6; any sane search lands near it
    assert h.best().value >= 50.0


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

def test_executor_orders_results_and_memoizes():
    space = golden_space()
    calls = []

    def obj(p):
        calls.append(space.key(p))
        return float(p["inter_op"])

    ex = EvaluationExecutor(obj, space, parallelism=2, backend="thread")
    pts = [{"inter_op": i, "intra_op": 0, "build": 1} for i in (3, 1, 2)]
    out = ex.evaluate(pts)
    assert [r.value for r in out] == [3.0, 1.0, 2.0]  # submission order
    out2 = ex.evaluate(pts)  # second pass: pure cache hits
    assert [r.value for r in out2] == [3.0, 1.0, 2.0]
    assert all(r.meta.get("memoized") for r in out2)
    assert len(calls) == 3
    ex.close()


def test_executor_failure_isolation():
    """A crashing configuration scores -inf; the pool survives and keeps
    evaluating (the paper's failed-run semantics)."""
    space = golden_space()

    def obj(p):
        if p["inter_op"] % 2 == 0:
            raise RuntimeError("OOM")
        return 1.0

    ex = EvaluationExecutor(obj, space, parallelism=3, backend="thread")
    pts = [{"inter_op": i, "intra_op": 0, "build": 1} for i in range(1, 9)]
    out = ex.evaluate(pts)
    assert [r.value for r in out] == [1.0, -math.inf] * 4
    assert all("error" in r.meta for r in out if r.value == -math.inf)
    # pool still alive for the next batch
    more = ex.evaluate([{"inter_op": 9, "intra_op": 0, "build": 1}])
    assert more[0].value == 1.0
    ex.close()


def test_executor_timeout_scores_neg_inf():
    space = golden_space()

    def obj(p):
        if p["inter_op"] == 1:
            time.sleep(30)
        return 1.0

    ex = EvaluationExecutor(obj, space, parallelism=2, backend="thread",
                            timeout=0.3)
    out = ex.evaluate([{"inter_op": 1, "intra_op": 0, "build": 1},
                       {"inter_op": 2, "intra_op": 0, "build": 1}])
    assert out[0].value == -math.inf and out[0].meta.get("timeout")
    assert out[1].value == 1.0
    ex.close()


def test_executor_timeout_queued_task_not_poisoned():
    """A task still queued when its wait expires was never measured: it must
    be run inline, not recorded (and memoized!) as a failure."""
    space = golden_space()

    def obj(p):
        if p["inter_op"] == 1:
            time.sleep(30)
        return float(p["inter_op"])

    ex = EvaluationExecutor(obj, space, parallelism=1, backend="thread",
                            timeout=0.3)
    out = ex.evaluate([{"inter_op": 1, "intra_op": 0, "build": 1},
                       {"inter_op": 2, "intra_op": 0, "build": 1}])
    assert out[0].value == -math.inf and out[0].meta.get("timeout")
    assert out[1].value == 2.0 and "timeout" not in out[1].meta
    ex.close()


def test_timeout_implies_pool_backend():
    """--eval-timeout must bound running evaluations even at parallelism=1,
    which the serial backend cannot do."""
    space = golden_space()
    ex = EvaluationExecutor(lambda p: 1.0, space, parallelism=1, timeout=0.2)
    assert ex.backend == "thread"
    ex.close()
    # without a timeout, parallelism=1 keeps the bit-for-bit serial path
    assert EvaluationExecutor(lambda p: 1.0, space, parallelism=1).backend == "serial"


def test_executor_duplicate_points_evaluated_once():
    space = golden_space()
    calls = []

    def obj(p):
        calls.append(1)
        return 1.0

    ex = EvaluationExecutor(obj, space, parallelism=1)
    p = {"inter_op": 1, "intra_op": 0, "build": 1}
    out = ex.evaluate([p, dict(p), dict(p)])
    assert len(calls) == 1
    assert [r.value for r in out] == [1.0, 1.0, 1.0]


def test_memo_cache_process_safe_roundtrip():
    cache = MemoCache.process_safe()
    cache.put(("k",), EvalResult({"a": 1}, 2.0, 0.1, {"m": 1}))
    hit = cache.get(("k",))
    assert hit.value == 2.0 and hit.meta == {"m": 1}
    assert cache.get(("missing",)) is None
    assert len(cache) == 1


def test_process_backend_with_picklable_objective():
    space = golden_space()
    ex = EvaluationExecutor(golden_objective, space, parallelism=2,
                            backend="process")
    pts = space.sample(np.random.default_rng(0), 3)
    out = ex.evaluate(pts)
    assert [r.value for r in out] == [
        pytest.approx(golden_objective(p)) for p in pts]
    ex.close()


# ---------------------------------------------------------------------------
# tuner integration: budgets, checkpointing, protocol
# ---------------------------------------------------------------------------

def test_mid_batch_checkpoint_resume(tmp_path):
    """Kill a run mid-batch (legacy barrier loop); the checkpoint holds only
    completed batches and resuming finishes the job without duplicating
    evaluations.  (The async-loop equivalent lives in test_async_loop.py.)"""
    ck = tmp_path / "t.json"
    state = {"evals": 0}

    def obj(p):
        state["evals"] += 1
        if state["evals"] == 10:  # die inside the third 4-point batch
            raise KeyboardInterrupt()  # not failure-isolated: a real abort
        return golden_objective(p)

    t1 = Tuner(obj, golden_space(),
               TunerConfig(algorithm="random", budget=16, seed=2,
                           verbose=False, parallelism=1, batch_size=4,
                           loop="batch", checkpoint_path=str(ck)))
    with pytest.raises(KeyboardInterrupt):
        t1.run()
    # only the two completed batches made it into history + checkpoint
    assert len(t1.history) == 8
    assert t1.history.n_pending() == 0  # in-flight marks were cleaned up
    saved = json.loads(ck.read_text())
    assert len(saved) == 8
    assert [r["point"] for r in saved] == t1.history.points()

    # resume: replays the 8 completed evals, finishes the remaining budget
    t2 = Tuner(golden_objective, golden_space(),
               TunerConfig(algorithm="random", budget=16, seed=2,
                           verbose=False, parallelism=4,
                           loop="batch", checkpoint_path=str(ck)))
    h2 = t2.run()
    t2.close()
    assert len(h2) == 16
    assert h2.points()[:8] == t1.history.points()
    keys = {golden_space().key(p) for p in h2.points()}
    assert len(keys) == 16  # no duplicated measurements after resume


def test_nms_resume_with_speculative_batches_matches_uninterrupted():
    """Replaying a checkpoint must not feed unconsumed speculative probes
    into the NMS state machine: a resumed run continues exactly like an
    uninterrupted one (NMS only draws rng at init, so traces are equal).
    Pinned to the batch loop, whose submission-order tells make the full
    trace deterministic at parallelism=4; async-loop NMS reconciliation
    is covered in test_async_loop.py."""
    def run_to(budget, ck=None):
        t = Tuner(golden_objective, golden_space(),
                  TunerConfig(algorithm="nms", budget=budget, seed=1,
                              verbose=False, parallelism=4,
                              loop="batch", checkpoint_path=ck))
        h = t.run()
        t.close()
        return h

    full = run_to(24)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        ck = str(pathlib.Path(d) / "nms.json")
        run_to(12, ck)
        resumed = run_to(24, ck)
    assert resumed.points() == full.points()
    assert [e.value for e in resumed.evals] == pytest.approx(
        [e.value for e in full.evals])


def test_exhaustive_grid_exhaustion_ends_cleanly():
    """budget > grid: the sweep completes and the tuner stops, no crash."""
    from repro.core import IntDim
    space = SearchSpace([IntDim("a", 0, 3, 1)])
    t = Tuner(lambda p: float(p["a"]), space,
              TunerConfig(algorithm="exhaustive", budget=100, seed=0,
                          verbose=False, parallelism=3))
    h = t.run()
    t.close()
    assert len(h) == 4  # the whole grid, exactly once
    assert h.best().point["a"] == 3


def test_wall_clock_budget_stops_early():
    def obj(p):
        time.sleep(0.02)
        return golden_objective(p)

    t = Tuner(obj, golden_space(),
              TunerConfig(algorithm="random", budget=10_000, seed=0,
                          verbose=False, parallelism=2,
                          wall_clock_budget=0.4))
    t0 = time.time()
    h = t.run()
    t.close()
    assert 0 < len(h) < 10_000
    assert time.time() - t0 < 5.0


def test_evaluator_protocol_explicit():
    # plain scalar callables are adapted
    ev = as_evaluator(lambda p: 3)
    assert isinstance(ev, FunctionEvaluator)
    assert ev({"x": 1}) == (3.0, {})
    # evaluators with returns_meta pass through untouched
    class My(Evaluator):
        def __call__(self, p):
            return 1.0, {"tag": "m"}
    m = My()
    assert as_evaluator(m) is m
    # tuple returns from plain callables are a loud error, not duck-typing
    with pytest.raises(TypeError, match="returns_meta"):
        as_evaluator(lambda p: (1.0, {}))({"x": 1})


def test_tuner_records_meta_from_evaluator():
    class My(Evaluator):
        def __call__(self, p):
            return float(p["inter_op"]), {"tag": p["inter_op"]}

    t = Tuner(My(), golden_space(),
              TunerConfig(algorithm="random", budget=4, seed=0,
                          verbose=False))
    h = t.run()
    assert all(e.meta["tag"] == e.point["inter_op"] for e in h.evals)


def test_evaluator_declared_cost_overrides_wall_clock():
    """meta["cost_seconds"] is recorded as the evaluation cost (the signal
    cost-aware acquisition trains on), overriding the wall-clock timing;
    bogus declarations fall back to the measured time."""
    class Declared(Evaluator):
        def __call__(self, p):
            return 1.0, {"cost_seconds": 7.5}

    ex = EvaluationExecutor(Declared(), golden_space(), parallelism=1)
    out = ex.evaluate([{"inter_op": 1, "intra_op": 0, "build": 1}])
    ex.close()
    assert out[0].cost_seconds == 7.5
    assert out[0].meta["cost_seconds"] == 7.5

    class Bogus(Evaluator):
        def __call__(self, p):
            time.sleep(0.01)
            return 1.0, {"cost_seconds": -3.0}

    ex = EvaluationExecutor(Bogus(), golden_space(), parallelism=1)
    out = ex.evaluate([{"inter_op": 1, "intra_op": 0, "build": 1}])
    ex.close()
    assert out[0].cost_seconds >= 0.01  # fell back to wall clock
