"""ssm_scan / gla_scan Pallas kernels vs oracles, incl. chunked forms."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # Pallas kernel sweeps


def _mk(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


SSM_SWEEP = [
    # B, S, D, N, chunk, block_d
    (1, 16, 8, 4, 8, 8),
    (2, 50, 12, 8, 16, 8),
    (1, 33, 24, 16, 8, 16),
    (2, 64, 16, 4, 32, 4),
]


@pytest.mark.parametrize("case", SSM_SWEEP, ids=[str(c) for c in SSM_SWEEP])
def test_ssm_scan_pallas_matches_naive(rng, case):
    B, S, D, N, chunk, block_d = case
    x = _mk(rng, B, S, D)
    dt = jnp.abs(_mk(rng, B, S, D)) * 0.1
    A = -jnp.abs(_mk(rng, D, N))
    Bi, Ci, Dv = _mk(rng, B, S, N), _mk(rng, B, S, N), _mk(rng, D)
    y_naive = ops.ssm_scan(x, dt, A, Bi, Ci, Dv, impl="ref")
    y_chunk = ops.ssm_scan(x, dt, A, Bi, Ci, Dv, impl="chunked", chunk=chunk)
    y_pal = ops.ssm_scan(x, dt, A, Bi, Ci, Dv, impl="pallas", chunk=chunk,
                         block_d=block_d)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_naive),
                               atol=2e-4, rtol=2e-4)


def test_ssm_scan_state_continuity(rng):
    """Chunked scan's carried state == running the naive scan in two halves."""
    B, S, D, N = 1, 32, 8, 4
    x = _mk(rng, B, S, D)
    dt = jnp.abs(_mk(rng, B, S, D)) * 0.1
    A = -jnp.abs(_mk(rng, D, N))
    Bi, Ci, Dv = _mk(rng, B, S, N), _mk(rng, B, S, N), _mk(rng, D)
    y_full, h_full = ref.ssm_scan_ref(x, dt, A, Bi, Ci, Dv)
    _, h1 = ref.ssm_scan_ref(x[:, :16], dt[:, :16], A, Bi[:, :16], Ci[:, :16], Dv)
    y2, h2 = ref.ssm_scan_ref(x[:, 16:], dt[:, 16:], A, Bi[:, 16:], Ci[:, 16:],
                              Dv, h0=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 16:]),
                               atol=1e-5)


GLA_SWEEP = [
    # B, S, H, dk, dv, chunk
    (1, 16, 2, 8, 8, 8),
    (2, 45, 3, 8, 8, 16),
    (1, 40, 4, 16, 16, 8),
]


@pytest.mark.parametrize("case", GLA_SWEEP, ids=[str(c) for c in GLA_SWEEP])
def test_gla_scan_pallas_matches_naive(rng, case):
    B, S, H, dk, dv, chunk = case
    r, k, v = _mk(rng, B, S, H, dk), _mk(rng, B, S, H, dk), _mk(rng, B, S, H, dv)
    w = jnp.exp(-jnp.exp(_mk(rng, B, S, H, dk) * 0.5 - 1.0))
    u = _mk(rng, H, dk)
    y_naive = ops.gla_scan(r, k, v, w, u, impl="ref")
    y_chunk = ops.gla_scan(r, k, v, w, u, impl="chunked", chunk=chunk)
    y_pal = ops.gla_scan(r, k, v, w, u, impl="pallas", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_naive),
                               atol=2e-4, rtol=2e-3)


def test_gla_strong_decay_stable(rng):
    """Very strong decays must not produce inf/nan in the chunked form."""
    B, S, H, dk, dv = 1, 64, 2, 8, 8
    r, k, v = _mk(rng, B, S, H, dk), _mk(rng, B, S, H, dk), _mk(rng, B, S, H, dv)
    w = jnp.full((B, S, H, dk), 1e-6)  # near-total forgetting per step
    u = _mk(rng, H, dk)
    y = ops.gla_scan(r, k, v, w, u, impl="chunked", chunk=32)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_matches_ref(rng, dtype):
    x = _mk(rng, 5, 33, 64, dtype=dtype)
    s = _mk(rng, 64, dtype=jnp.float32)
    out_ref = ops.rmsnorm(x, s, impl="ref")
    out_pal = ops.rmsnorm(x, s, impl="pallas", block_rows=8)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out_pal, np.float32),
                               np.asarray(out_ref, np.float32), atol=tol,
                               rtol=tol)
