"""Compile-once GP surrogate: padded-vs-exact equivalence, warm-started
refits, zero-recompile-within-bucket, fused jitted acquisition, and
cost-aware EI-per-second.

The compile-once contract (gp.py module docstring): every array entering
a jitted function is padded to a power-of-two bucket with a validity
mask, masked rows get a unit diagonal / zero cross-covariance so the
Cholesky and MLL are *exact* on the live prefix, and history growth
within a bucket must add zero jit-cache entries.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GaussianProcess,
    History,
    IntDim,
    Observation,
    SearchSpace,
    Tuner,
    TunerConfig,
)
from repro.core import gp as gp_module
from repro.core.bayesopt import BayesOpt, _norm_cdf
from repro.core.gp import _neg_mll, _posterior, bucket_size


def _toy_data(n=11, d=3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.random((n, d))
    y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2 - 0.5 * X[:, 2]
    return X, y


def _params(d, dtype=jnp.float32):
    return {
        "log_ls": jnp.full((d,), np.log(0.3), dtype),
        "log_sigma2": jnp.asarray(0.2, dtype),
        "log_noise": jnp.asarray(np.log(1e-3), dtype),
    }


def _pad(a, b):
    pad = [(0, b - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(np.asarray(a, np.float32), pad)


# ---------------------------------------------------------------------------
# padded-vs-exact equivalence of the masked kernels
# ---------------------------------------------------------------------------

def test_bucket_schedule():
    assert [bucket_size(n) for n in (1, 8, 9, 16, 17, 100)] == \
        [8, 8, 16, 16, 32, 128]
    # O(log n) buckets: 1..1000 training-set sizes hit only 8 shapes
    assert len({bucket_size(n) for n in range(1, 1001)}) == 8


@pytest.mark.parametrize("kind", ["rbf", "matern52"])
def test_padded_neg_mll_matches_exact(kind):
    X, y = _toy_data()
    n, d = X.shape
    p = _params(d)
    exact = _neg_mll(p, jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                     jnp.ones(n, jnp.float32), kind)
    b = bucket_size(n)
    assert b > n  # this case genuinely pads
    mask = jnp.asarray((np.arange(b) < n).astype(np.float32))
    padded = _neg_mll(p, jnp.asarray(_pad(X, b)), jnp.asarray(_pad(y, b)),
                      mask, kind)
    np.testing.assert_allclose(float(padded), float(exact), rtol=1e-5)


@pytest.mark.parametrize("kind", ["rbf", "matern52"])
def test_padded_posterior_matches_exact(kind):
    X, y = _toy_data()
    n, d = X.shape
    Xs = np.random.default_rng(1).random((5, d))
    p = _params(d)
    mu_e, var_e = _posterior(p, jnp.asarray(X, jnp.float32),
                             jnp.asarray(y, jnp.float32),
                             jnp.ones(n, jnp.float32),
                             jnp.asarray(Xs, jnp.float32), kind)
    bn, bm = bucket_size(n), bucket_size(len(Xs))
    mask = jnp.asarray((np.arange(bn) < n).astype(np.float32))
    mu_p, var_p = _posterior(p, jnp.asarray(_pad(X, bn)),
                             jnp.asarray(_pad(y, bn)), mask,
                             jnp.asarray(_pad(Xs, bm)), kind)
    np.testing.assert_allclose(np.asarray(mu_p)[:5], np.asarray(mu_e),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_p)[:5], np.asarray(var_e),
                               rtol=1e-4, atol=1e-6)


def test_gp_end_to_end_padding_invariant():
    """A GP padded to a big bucket predicts the same as a barely-padded
    one: the fit trajectory and posterior only see the live prefix."""
    X, y = _toy_data(n=13)
    Xs = np.random.default_rng(2).random((7, X.shape[1]))
    small = GaussianProcess(min_bucket=16).fit(X, y).posterior(Xs)
    big = GaussianProcess(min_bucket=64).fit(X, y).posterior(Xs)
    # fp32 reassociation across 120 Adam steps accumulates ~1e-3 relative
    # drift between bucket sizes; the posteriors must still agree closely
    np.testing.assert_allclose(small.mu, big.mu, rtol=1e-2, atol=5e-3)
    np.testing.assert_allclose(small.sigma, big.sigma, rtol=5e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# warm-started refits
# ---------------------------------------------------------------------------

def test_warm_started_refit_stays_finite_and_accurate():
    X, y = _toy_data(n=24, seed=3)
    gp = GaussianProcess()
    gp.fit(X[:20], y[:20])
    assert not gp.last_fit_was_warm
    cold_params = gp.params
    gp.fit(X, y, params0=cold_params)  # 4 new rows, short warm schedule
    assert gp.last_fit_was_warm
    for leaf in gp.params.values():
        assert np.isfinite(np.asarray(leaf)).all()
    post = gp.posterior(X)
    assert np.isfinite(post.mu).all() and np.isfinite(post.sigma).all()
    # warm refit stays near-interpolating like a cold fit does
    assert np.sqrt(np.mean((post.mu - y) ** 2)) < 0.1


def test_engine_warm_start_policy():
    """Cold refits below warm_start_min_n (trace-pinned regime), warm
    refinement above."""
    space = SearchSpace([IntDim("x", 0, 63), IntDim("z", 0, 7)])

    def drive(engine, n_iters):
        h = History(space)
        for _ in range(n_iters):
            p = engine.ask(1, h)[0]
            v = float(p["x"] * 0.1 - (p["z"] - 3) ** 2)
            engine.tell([Observation(point=p, value=v, cost_seconds=0.05)])
            h.add(p, v, 0.05)
        return h

    eng = BayesOpt(space, seed=0, warm_start_min_n=12)
    drive(eng, 11)
    assert not eng._gp.last_fit_was_warm  # 10 rows at the last fit: cold
    drive_more = BayesOpt(space, seed=0, warm_start_min_n=12)
    drive(drive_more, 16)
    assert drive_more._gp.last_fit_was_warm  # >= 12 rows: warm refinement
    off = BayesOpt(space, seed=0, warm_start=False, warm_start_min_n=12)
    drive(off, 16)
    assert not off._gp.last_fit_was_warm


# ---------------------------------------------------------------------------
# compile-once: zero recompiles while the history grows within a bucket
# ---------------------------------------------------------------------------

def test_zero_recompiles_within_bucket():
    # grid of 341: the candidate set (341 - n unseen points) stays inside
    # the 512 bucket for every n this test reaches, so the candidate axis
    # never crosses a bucket boundary mid-test
    space = SearchSpace([IntDim("x", 0, 30), IntDim("z", 0, 10)])
    eng = BayesOpt(space, seed=0)
    h = History(space)

    def step():
        p = eng.ask(1, h)[0]
        v = float(-(p["x"] - 17) ** 2 - p["z"])
        eng.tell([Observation(point=p, value=v, cost_seconds=0.01)])
        h.add(p, v, 0.01)

    # warm the bucket: cross into the 32-row training bucket (n=17)
    while len(h) < 18:
        step()
    entries = gp_module.jit_cache_entries()
    while len(h) < 30:  # 12 more asks, all inside the 32-row bucket
        step()
    assert gp_module.jit_cache_entries() == entries, \
        "history growth within a bucket must not trigger XLA recompiles"
    assert eng.jit_misses[18:] == [0] * (len(eng.jit_misses) - 18)
    assert len(eng.ask_seconds) == len(eng.jit_misses) == 30


# ---------------------------------------------------------------------------
# fused jitted acquisition == vectorized numpy fallback
# ---------------------------------------------------------------------------

def _seeded_engine_pair(acquisition):
    space = SearchSpace([IntDim("x", 0, 15), IntDim("z", 0, 12)])
    jit_eng = BayesOpt(space, seed=7, acquisition=acquisition)
    np_eng = BayesOpt(space, seed=7, acquisition=acquisition,
                      jit_acquisition=False)
    return space, jit_eng, np_eng


@pytest.mark.parametrize("acquisition", ["smsego", "ucb"])
def test_jit_and_numpy_acquisition_agree(acquisition):
    """smsego/ucb are pure mul/add on the posterior, so the fused jitted
    path and the numpy fallback produce the *same suggestion sequence*."""
    space, jit_eng, np_eng = _seeded_engine_pair(acquisition)

    def obj(p):
        return float(np.exp(-((p["x"] - 9) / 4) ** 2) * 20 + 0.5 * p["z"])

    h_j, h_n = History(space), History(space)
    for _ in range(14):
        pj = jit_eng.ask(1, h_j)[0]
        pn = np_eng.ask(1, h_n)[0]
        assert pj == pn  # same ranking from both scoring paths
        jit_eng.tell([Observation(point=pj, value=obj(pj))])
        h_j.add(pj, obj(pj))
        np_eng.tell([Observation(point=pn, value=obj(pn))])
        h_n.add(pn, obj(pn))


def test_jit_and_numpy_ei_values_agree():
    """EI involves erf, whose jax-f32 and scipy-f64 implementations differ
    in the last ulp — so compare acquisition *values* to tolerance rather
    than demanding identical tie-breaks."""
    rng = np.random.default_rng(11)
    X = rng.random((10, 2))
    y = np.sin(4 * X[:, 0]) + X[:, 1]
    gp = GaussianProcess().fit(X, y)
    Xs = rng.random((17, 2))
    y_best = float(y.max())
    _, acq_jit = gp.acquisition_rank(Xs, "ei", y_best)
    post = gp.posterior(Xs)
    z = (post.mu - y_best) / np.maximum(post.sigma, 1e-12)
    from repro.core.bayesopt import _norm_pdf
    acq_np = (post.mu - y_best) * _norm_cdf(z) + post.sigma * _norm_pdf(z)
    np.testing.assert_allclose(acq_jit, acq_np, rtol=1e-4, atol=1e-6)


def test_acquisition_rank_nonfinite_fallback(monkeypatch):
    """If the fused acquisition comes back non-finite (fp32 blowup), the
    ranking is retried once with the same big noise floor posterior()
    uses — the jitted path must not silently rank NaNs."""
    rng = np.random.default_rng(0)
    X = rng.random((9, 2))
    y = np.sin(3 * X[:, 0])
    gp = GaussianProcess().fit(X, y)
    Xs = rng.random((6, 2))
    noise_per_call = []
    real = gp_module._acq_rank

    def flaky(params, *args):
        noise_per_call.append(float(np.exp(np.asarray(params["log_noise"]))))
        order, acq = real(params, *args)
        if len(noise_per_call) == 1:  # first attempt: pretend fp32 blew up
            return order, jnp.full_like(acq, jnp.nan)
        return order, acq

    monkeypatch.setattr(gp_module, "_acq_rank", flaky)
    order, acq = gp.acquisition_rank(Xs, "ei", float(y.max()))
    assert len(noise_per_call) == 2  # retried exactly once...
    assert noise_per_call[1] == pytest.approx(0.1)  # ...with the safe floor
    assert np.isfinite(acq).all()
    assert sorted(order.tolist()) == list(range(len(Xs)))


def test_vectorized_erf_matches_math_erf():
    z = np.linspace(-4.0, 4.0, 161)
    expect = np.array([0.5 * (1.0 + math.erf(v / math.sqrt(2))) for v in z])
    got = _norm_cdf(z)
    assert isinstance(got, np.ndarray) and got.shape == z.shape
    np.testing.assert_allclose(got, expect, atol=2e-7)


# ---------------------------------------------------------------------------
# cost-aware acquisition (EI-per-second)
# ---------------------------------------------------------------------------

_COST_SPACE = SearchSpace([IntDim("x", 0, 19)])
_COST_OBSERVED = (1, 4, 7, 12, 15, 18)


def _two_peak_value(p):
    """Two value peaks of nearly equal height: the cheap one at x=4, the
    slightly better one at x=15 — pure EI chases the right peak, while
    EI-per-second should settle for the almost-as-good cheap one."""
    x = p["x"]
    return float(10.0 * np.exp(-((x - 4) / 3.0) ** 2)
                 + 10.6 * np.exp(-((x - 15) / 3.0) ** 2))


def _step_cost(p):
    return 40.0 if p["x"] >= 10 else 0.2


def _cost_setup():
    """Value GP + cost GP fit on the sparse two-peak history."""
    pts = [{"x": x} for x in _COST_OBSERVED]
    X = _COST_SPACE.encode_many(pts)
    y = np.array([_two_peak_value(p) for p in pts])
    cost = np.array([_step_cost(p) for p in pts])
    gp = GaussianProcess().fit(X, y)
    cost_gp = GaussianProcess().fit(X, np.log(cost))
    cands = [p for p in _COST_SPACE.enumerate()
             if p["x"] not in _COST_OBSERVED]
    Xs = _COST_SPACE.encode_many(cands)
    return gp, cost_gp, cands, Xs, float(y.max()), float(cost.mean())


def test_cost_aware_rank_is_exact_reweighting():
    """EI-per-second == EI / (relative predicted cost)^alpha, elementwise."""
    gp, cost_gp, _, Xs, y_best, mean_cost = _cost_setup()
    _, acq_plain = gp.acquisition_rank(Xs, "ei", y_best)
    _, acq_ca = gp.acquisition_rank(Xs, "ei", y_best, cost_gp=cost_gp,
                                    cost_alpha=1.0, mean_cost=mean_cost)
    rel = np.exp(cost_gp.posterior(Xs).mu) / mean_cost
    rel = np.clip(rel, 1e-2, 1e2)
    expect = np.where(acq_plain > 0, acq_plain / rel, acq_plain * rel)
    np.testing.assert_allclose(acq_ca, expect, rtol=1e-3, atol=1e-7)


def test_cost_aware_rank_prefers_cheap_probes():
    gp, cost_gp, cands, Xs, y_best, mean_cost = _cost_setup()
    order_plain, _ = gp.acquisition_rank(Xs, "ei", y_best)
    order_ca, _ = gp.acquisition_rank(Xs, "ei", y_best, cost_gp=cost_gp,
                                      cost_alpha=1.0, mean_cost=mean_cost)
    # pure EI tops out next to the (expensive) higher peak; EI-per-second
    # moves the top pick to the cheap peak's neighborhood
    assert cands[order_plain[0]]["x"] >= 10
    assert cands[order_ca[0]]["x"] < 10
    # alpha=0 (full budget remaining) disables the reweighting entirely
    order_a0, _ = gp.acquisition_rank(Xs, "ei", y_best, cost_gp=cost_gp,
                                      cost_alpha=0.0, mean_cost=mean_cost)
    assert list(order_a0) == list(order_plain)


def _build_cost_history(engine):
    h = History(_COST_SPACE)
    for x in _COST_OBSERVED:  # both regions measured, with their costs
        p = {"x": x}
        engine.tell([Observation(point=p, value=_two_peak_value(p),
                                 cost_seconds=_step_cost(p))])
        h.add(p, _two_peak_value(p), _step_cost(p))
    return h


def test_cost_aware_engine_deterministic_selection():
    """Same seed, same history: the cost_aware knob deterministically moves
    the suggestion from the expensive peak into the cheap region."""
    plain = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2)
    pick_plain = plain.ask(1, _build_cost_history(plain))[0]
    aware = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2,
                     cost_aware=True)
    pick_aware = aware.ask(1, _build_cost_history(aware))[0]
    assert pick_plain["x"] >= 10  # pure EI chases the higher peak
    assert pick_aware["x"] < 10   # EI-per-second prefers the cheap peak
    assert aware._cost_gp is not None  # cost model actually fit
    # determinism: a fresh engine on the same history reproduces the pick
    aware2 = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2,
                      cost_aware=True)
    assert aware2.ask(1, _build_cost_history(aware2))[0] == pick_aware


def test_cost_gp_follows_warm_start_policy():
    """The cost GP obeys the same warm-start policy as the value GP:
    cold below warm_start_min_n (and always when warm_start=False), warm
    refinement above once previous params exist."""
    aware = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2,
                     cost_aware=True, warm_start_min_n=4)
    h = _build_cost_history(aware)  # 6 rows >= min_n
    aware.ask(1, h)
    assert not aware._cost_gp.last_fit_was_warm  # no previous fit yet
    aware.ask(1, h)
    assert aware._cost_gp.last_fit_was_warm  # refit above min_n: warm
    off = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2,
                   cost_aware=True, warm_start=False, warm_start_min_n=4)
    h2 = _build_cost_history(off)
    off.ask(1, h2)
    off.ask(1, h2)
    assert not off._cost_gp.last_fit_was_warm
    cold = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2,
                    cost_aware=True, warm_start_min_n=50)
    h3 = _build_cost_history(cold)
    cold.ask(1, h3)
    cold.ask(1, h3)
    assert not cold._cost_gp.last_fit_was_warm  # 6 rows < min_n: cold


def test_cost_aware_budget_ramp():
    """With most of the wall clock left the reweighting is off (alpha=0);
    near exhaustion it is fully on."""
    space = SearchSpace([IntDim("x", 0, 19)])
    eng = BayesOpt(space, seed=0, cost_aware=True)
    assert eng._cost_alpha() == 1.0  # no budget info: full EI-per-second
    eng.note_budget(1.0)
    assert eng._cost_alpha() == 0.0
    eng.note_budget(0.25)
    assert eng._cost_alpha() == pytest.approx(0.75)
    eng.note_budget(None)
    assert eng._cost_alpha() == 1.0


def test_cost_aware_budget_ramp_edge_cases():
    """Alpha clamps at the drained end, tolerates out-of-range fractions,
    and stays inert without a wall-clock budget."""
    space = SearchSpace([IntDim("x", 0, 19)])
    eng = BayesOpt(space, seed=0, cost_aware=True)
    # budget fully drained: alpha saturates at 1, never beyond
    eng.note_budget(0.0)
    assert eng._cost_alpha() == 1.0
    # fractions outside [0, 1] (clock skew, rounding) clamp cleanly
    eng.note_budget(-0.5)
    assert eng._cost_alpha() == 1.0
    eng.note_budget(1.5)
    assert eng._cost_alpha() == 0.0
    # no wall-clock budget configured: the ramp is inert — a non-cost-
    # aware engine keeps alpha pinned regardless of what the tuner reports
    plain = BayesOpt(space, seed=0)
    plain.note_budget(0.1)
    assert plain.budget_fraction_remaining == 0.1
    assert not plain.cost_aware  # note_budget is recorded but unused


def test_cost_aware_repeated_asks_are_deterministic_at_fixed_state():
    """EI-per-second ranking is a pure function of (GP state, candidate
    set): asking the same engine repeatedly at a fixed history returns
    the same suggestion, and the drained-budget alpha does not drift."""
    aware = BayesOpt(_COST_SPACE, seed=0, acquisition="ei", n_init=2,
                     cost_aware=True)
    h = _build_cost_history(aware)
    aware.note_budget(0.0)  # drained: maximal cost pressure, stable
    picks = [aware.ask(1, h)[0] for _ in range(3)]
    assert picks[0] == picks[1] == picks[2]
    assert aware._cost_alpha() == 1.0  # asks must not perturb the ramp
    # the full candidate ordering is reproducible, not just the top pick
    cands = [p for p in _COST_SPACE.enumerate()
             if p["x"] not in _COST_OBSERVED]
    Xs = _COST_SPACE.encode_many(cands)
    order1, acq1 = aware._gp.acquisition_rank(
        Xs, "ei", float(max(_two_peak_value({"x": x})
                            for x in _COST_OBSERVED)),
        cost_gp=aware._cost_gp, cost_alpha=1.0,
        mean_cost=aware.mean_cost_seconds)
    order2, acq2 = aware._gp.acquisition_rank(
        Xs, "ei", float(max(_two_peak_value({"x": x})
                            for x in _COST_OBSERVED)),
        cost_gp=aware._cost_gp, cost_alpha=1.0,
        mean_cost=aware.mean_cost_seconds)
    assert list(order1) == list(order2)
    np.testing.assert_array_equal(np.asarray(acq1), np.asarray(acq2))


def test_tuner_threads_cost_aware_knob():
    space = SearchSpace([IntDim("x", 0, 9)])
    t = Tuner(lambda p: float(p["x"]), space,
              TunerConfig(algorithm="bo", budget=3, verbose=False,
                          cost_aware=True))
    assert t.engine.cost_aware is True
    t.close()
    with pytest.raises(ValueError, match="cost_aware"):
        Tuner(lambda p: float(p["x"]), space,
              TunerConfig(algorithm="ga", budget=3, verbose=False,
                          cost_aware=True))


def test_cost_aware_tuner_run_end_to_end():
    """A cost-aware BO tuning run under a wall-clock budget completes and
    records costs; the engine saw budget-pressure updates."""
    space = SearchSpace([IntDim("x", 0, 19), IntDim("z", 0, 5)])

    def obj(p):
        return float(p["x"] * 0.3 + p["z"])

    t = Tuner(obj, space,
              TunerConfig(algorithm="bo", budget=12, seed=1, verbose=False,
                          cost_aware=True, wall_clock_budget=30.0))
    h = t.run()
    t.close()
    assert len(h) == 12
    assert t.engine.budget_fraction_remaining is not None
    assert 0.0 <= t.engine.budget_fraction_remaining <= 1.0


# ---------------------------------------------------------------------------
# History: incremental encoding cache
# ---------------------------------------------------------------------------

def test_history_encoded_incremental_matches_full_reencode():
    space = SearchSpace([IntDim("x", 0, 9), IntDim("z", 0, 4)])
    h = History(space)
    rng = np.random.default_rng(0)
    for i in range(7):
        h.add(space.sample(rng, 1)[0], float(i), cost_seconds=0.1 * i)
    X1, y1 = h.encoded()
    np.testing.assert_array_equal(X1, space.encode_many(h.points()))
    np.testing.assert_array_equal(y1, [e.value for e in h.evals])
    # grow past the initial capacity; only new rows are encoded
    for i in range(40):
        h.add(space.sample(rng, 1)[0], float(100 + i), cost_seconds=0.5)
    X2, y2 = h.encoded()
    np.testing.assert_array_equal(X2, space.encode_many(h.points()))
    np.testing.assert_array_equal(y2, [e.value for e in h.evals])
    np.testing.assert_array_equal(h.costs(),
                                  [e.cost_seconds for e in h.evals])
    np.testing.assert_array_equal(h.values(), y2)


def test_history_encoded_returns_defensive_copies():
    space = SearchSpace([IntDim("x", 0, 9)])
    h = History(space)
    h.add({"x": 3}, 1.0)
    X, y = h.encoded()
    X[0, 0] = 99.0
    y[0] = -42.0
    X2, y2 = h.encoded()
    assert X2[0, 0] != 99.0 and y2[0] == 1.0
