"""The scheduler zoo behind the ``TrialScheduler`` seam.

Covers the seam contract itself (back-compat import identity, lifecycle
hooks), HyperBand's bracket plumbing (ladder shapes, completion-driven
budget split, replay routing), PBT's exploit/explore population
(admission, forks, doom, replay dedupe), resume equality for both new
schedulers, and — parametrized over all three — the preemption race:
a ``decide()``-issued preempt that lands after the completion must
record exactly once.
"""
import json

import pytest

from repro.core import (CatDim, IntDim, MultiFidelityConfig, SearchSpace,
                        Tuner, TunerConfig)
from repro.tuning import fidelity as fidelity_module
from repro.tuning.objective import Evaluator
from repro.tuning.schedulers import (HyperBandScheduler, PBTScheduler,
                                     RungScheduler, TrialScheduler,
                                     build_scheduler)
from repro.tuning.schedulers import asha as asha_module


def make_space() -> SearchSpace:
    return SearchSpace([IntDim("inter_op", 1, 4),
                        IntDim("intra_op", 0, 30, 10),
                        CatDim("build", (1, 2))])


def value_of(p):
    return float(3.0 * p["inter_op"] + 0.2 * p["intra_op"] + 7.0 * p["build"])


class ForkCapable(Evaluator):
    supports_fidelity = True
    supports_fork = True

    def __init__(self):
        self.calls = []  # (key-tuple, fidelity, resume_state)

    def __call__(self, p, fidelity=None, resume_state=None):
        f = 1.0 if fidelity is None else float(fidelity)
        self.calls.append(((p["inter_op"], p["intra_op"], p["build"]), f,
                           resume_state))
        warm = int((resume_state or {}).get("warm", 0))
        return value_of(p) + 0.01 * warm, {
            "fork_state": {"warm": warm + 1}, "cost_seconds": 0.001}


# ---------------------------------------------------------------------------
# the seam: back-compat + base lifecycle
# ---------------------------------------------------------------------------

def test_rungscheduler_import_paths_are_one_class():
    """``repro.tuning.fidelity`` keeps exporting the relocated class —
    existing imports, isinstance checks, and pickles stay valid."""
    assert fidelity_module.RungScheduler is asha_module.RungScheduler
    assert fidelity_module.RungScheduler is RungScheduler
    assert issubclass(RungScheduler, TrialScheduler)
    assert issubclass(HyperBandScheduler, TrialScheduler)
    assert issubclass(PBTScheduler, TrialScheduler)


def test_build_scheduler_maps_kinds():
    mf = MultiFidelityConfig(enabled=True, min_fidelity=1 / 9)
    assert isinstance(build_scheduler(mf), RungScheduler)
    mf.scheduler = "hyperband"
    assert isinstance(build_scheduler(mf), HyperBandScheduler)
    mf.scheduler = "pbt"
    assert isinstance(build_scheduler(mf, space=make_space()), PBTScheduler)
    with pytest.raises(ValueError, match="search space"):
        build_scheduler(mf)
    mf.scheduler = "sobol"
    with pytest.raises(ValueError, match="sobol"):
        build_scheduler(mf, space=make_space())


# ---------------------------------------------------------------------------
# HyperBand: bracket shapes, budget split, replay routing
# ---------------------------------------------------------------------------

def test_hyperband_bracket_shapes_and_offsets():
    """min_fidelity=1/9, eta=3: deepest ladder 1/9 -> 1/3 -> 1, then the
    staggered shallower brackets 1/3 -> 1 and the full-fidelity-only
    one.  Global rung ids are bracket offsets + inner rungs."""
    hb = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9)
    assert [b.n_rungs for b in hb.brackets] == [3, 2, 1]
    assert hb._offsets == [0, 3, 5]
    assert [round(b.base_fidelity, 6) for b in hb.brackets] \
        == [round(1 / 9, 6), round(1 / 3, 6), 1.0]
    # the brackets cap keeps the deepest ladders
    hb2 = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9, brackets=2)
    assert [b.n_rungs for b in hb2.brackets] == [3, 2]
    with pytest.raises(ValueError, match="brackets"):
        HyperBandScheduler(eta=3.0, min_fidelity=1 / 9, brackets=9)


def test_hyperband_admits_to_least_spent_bracket():
    hb = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9)
    acts = [hb.admit((i,), {"x": i}) for i in range(4)]
    # bracket 0 is cheapest per admission, so it absorbs several fresh
    # candidates before its cumulative spend passes bracket 1's
    assert acts[0].lineage == "b0"
    lineages = {a.lineage for a in acts}
    assert len(lineages) >= 2  # the split spreads across brackets
    # every admission entered its bracket's bottom rung at that fidelity
    for a in acts:
        i = int(a.lineage[1:])
        assert a.rung == hb._offsets[i]
        assert a.fidelity == pytest.approx(hb.brackets[i].base_fidelity)


def test_hyperband_spend_trueup_and_preempt_refund():
    hb = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9, brackets=1)
    act = hb.admit(("a",), {"x": 0})
    assert hb._spend[0] == pytest.approx(1 / 9)  # planned at dispatch
    hb.on_started(("a",), {"x": 0}, act.rung, lineage=act.lineage)
    # delivered more than planned (executor upgraded the request)
    hb.on_result(("a",), {"x": 0}, 5.0, act.rung, fidelity=1 / 3,
                 lineage=act.lineage)
    assert hb._spend[0] == pytest.approx(1 / 3)  # trued up
    # a cancelled preemption refunds the planned spend
    before = hb._spend[0]
    act2 = hb.admit(("b",), {"x": 1})
    hb.on_preempted(("b",), act2.rung, lineage=act2.lineage)
    assert hb._spend[0] == pytest.approx(before)


def test_hyperband_replay_routes_by_lineage_and_matches_live():
    """A crashed-and-replayed HyperBand equals the never-crashed one:
    same per-bracket results, promotion marks, and spend."""
    def feed(hb):
        recs = []
        for i in range(6):
            act = hb.admit((i,), {"x": i})
            hb.on_started((i,), {"x": i}, act.rung, lineage=act.lineage)
            hb.on_result((i,), {"x": i}, float(i), act.rung,
                         fidelity=act.fidelity, lineage=act.lineage)
            recs.append(((i,), {"x": i}, float(i), act.fidelity, act.rung,
                         act.lineage))
        return recs

    live = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9)
    recs = feed(live)

    resumed = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9)
    charged = sum(resumed.replay(k, p, v, f, rung=r, lineage=lin)
                  for k, p, v, f, r, lin in recs)
    assert charged == pytest.approx(sum(f for *_, f, _r, _l in recs))

    def state(hb):
        return [(sorted(map(repr, b.rungs[r].results)),
                 sorted(map(repr, b.rungs[r].promoted)))
                for b in hb.brackets for r in range(b.n_rungs)]
    assert state(resumed) == state(live)
    assert resumed._spend == pytest.approx(live._spend)
    # duplicates and preempted placeholders charge nothing
    k, p, v, f, r, lin = recs[0]
    assert resumed.replay(k, p, v, f, rung=r, lineage=lin) == 0.0
    assert resumed.replay(("z",), {"x": 9}, 1.0, 1.0, rung=0, lineage="b0",
                          meta={"preempted": True}) == 0.0


def test_hyperband_stats_rows_carry_bracket_and_global_rung():
    hb = HyperBandScheduler(eta=3.0, min_fidelity=1 / 9)
    rows = hb.stats()
    assert [r["rung"] for r in rows] == list(range(6))
    assert [r["bracket"] for r in rows] == [0, 0, 0, 1, 1, 2]
    snap = hb.snapshot()
    json.dumps(snap)  # wire-safe for job_status
    assert [b["bracket"] for b in snap["brackets"]] == [0, 1, 2]


# ---------------------------------------------------------------------------
# PBT: population, forks, doom, replay
# ---------------------------------------------------------------------------

def _pbt(population=4, **kw):
    kw.setdefault("exploit_quantile", 0.25)
    kw.setdefault("step_fidelity", 0.5)
    return PBTScheduler(make_space(), population=population, seed=3, **kw)


def _seed_population(s, n):
    """Admit n members and give each a first-step value."""
    for i in range(n):
        point = {"inter_op": 1 + i % 4, "intra_op": 10 * (i % 4), "build": 1}
        act = s.admit((i,), point)
        assert act is not None and act.lineage == f"m{i}"
        s.on_started((i,), point, act.rung, lineage=act.lineage)
        s.on_result((i,), point, float(i), act.rung, fidelity=act.fidelity,
                    lineage=act.lineage)


def test_pbt_admission_caps_at_population():
    s = _pbt(population=3)
    assert s.fresh_quota(10) == 3
    _seed_population(s, 3)
    assert s.fresh_quota(10) == 0
    assert s.admit((9,), {"inter_op": 1, "intra_op": 0, "build": 1}) is None


def test_pbt_under_population_defers_then_steps():
    """While under-populated, next_action yields to fresh admission —
    but only until a driver cycle passes with no admission (dry engine),
    then it steps the members it has rather than stall."""
    s = _pbt(population=4)
    _seed_population(s, 2)
    assert s.next_action() is None      # defer: let the driver admit
    act = s.next_action()               # no admit happened: step anyway
    assert act is not None and act.kind == "step"


def test_pbt_bottom_member_is_replaced_by_fork():
    s = _pbt(population=4)
    _seed_population(s, 4)
    act = s.next_action()
    # the bottom-quantile member (value 0.0) is culled; the replacement
    # clones a top-quantile donor's point (perturbed) and checkpoint
    assert act.kind == "fork"
    assert act.lineage == "m4"
    assert "m0" not in s._members
    assert s.n_forks == 1
    child = s._members["m4"]
    assert child.parent in {"m2", "m3"}


def test_pbt_fork_carries_donor_checkpoint():
    s = _pbt(population=4)
    _seed_population(s, 4)
    for m in s._members.values():
        m.state = {"warm": int(m.value) + 1}
    act = s.next_action()
    assert act.kind == "fork"
    donor = s._members[act.lineage].parent
    assert act.state == {"warm": {"m2": 3, "m3": 4}[donor]}


def _doom_running_m1(s):
    """Drive the scheduler into the race setup: m1's step is in flight
    when a completion re-ranks it into the bottom quantile (doomed)."""
    fork = s.next_action()            # m0 (bottom) replaced by fork m4
    assert fork.kind == "fork" and fork.lineage == "m4"
    s.on_started(None, fork.point, fork.rung, lineage=fork.lineage)
    step = s.next_action()            # m4 unvalued -> no cull: plain step
    assert step.kind == "step" and step.lineage == "m1"
    s.on_started(None, step.point, step.rung, lineage=step.lineage)
    # the fork's completion makes the population fully valued with m1
    # (value 1.0, still running) now in the bottom quantile: doomed
    s.on_result(None, fork.point, 50.0, fork.rung, lineage=fork.lineage)
    assert s._members["m1"].doomed
    assert s.decide(None, step.rung, lineage="m1") == "preempt"
    return step


def test_pbt_doomed_running_member_forks_exactly_once_via_result():
    """The preemption race, completion-wins arm: decide() says preempt,
    the executor reports the step already done, so the driver records it
    and calls on_result — which must fork exactly once (and
    on_preempted must NOT also fire)."""
    s = _pbt(population=4)
    _seed_population(s, 4)
    step = _doom_running_m1(s)
    forks_before = s.n_forks
    # completion won the race: the driver consumes the result normally
    s.on_result(None, step.point, 0.5, step.rung, lineage="m1")
    assert s.n_forks == forks_before + 1
    assert "m1" not in s._members
    # the doom mark was consumed: nothing left to preempt
    assert s.decide(None, step.rung, lineage="m1") == "continue"


def test_pbt_doomed_cancelled_member_forks_exactly_once_via_preempt():
    """The other arm: the preempt lands as cancelled, on_preempted forks
    the replacement, and there is no completion to double-fork on."""
    s = _pbt(population=4)
    _seed_population(s, 4)
    step = _doom_running_m1(s)
    forks_before = s.n_forks
    s.on_preempted(None, step.rung, lineage="m1")
    assert s.n_forks == forks_before + 1
    assert s.n_preempted == 1
    assert "m1" not in s._members


def test_pbt_replay_rebuilds_population_latest_step_wins():
    s = _pbt(population=4)
    s.replay((0,), {"inter_op": 1, "intra_op": 0, "build": 1}, 1.0, 0.5,
             rung=0, lineage="m0", meta={"fork_state": {"warm": 1}})
    s.replay((0,), {"inter_op": 2, "intra_op": 0, "build": 1}, 2.0, 0.5,
             rung=1, lineage="m0", meta={"fork_state": {"warm": 2}})
    # a duplicate of (m0, step 1) — the checkpoint-race artifact — and a
    # preempted placeholder both charge nothing
    assert s.replay((0,), {"inter_op": 2, "intra_op": 0, "build": 1}, 2.0,
                    0.5, rung=1, lineage="m0") == 0.0
    assert s.replay((1,), {"inter_op": 3, "intra_op": 0, "build": 1}, 3.0,
                    0.5, rung=0, lineage="m7",
                    meta={"preempted": True}) == 0.0
    m = s._members["m0"]
    assert (m.steps, m.value, m.point["inter_op"]) == (2, 2.0, 2)
    assert m.state == {"warm": 2}
    # lineage counter resumes past the replayed names: no collisions
    assert s._n_lineages >= 1
    act = s.admit((5,), {"inter_op": 4, "intra_op": 0, "build": 2})
    assert act.lineage not in {"m0", "m7"}


def test_pbt_snapshot_is_jsonable_and_names_lineage():
    s = _pbt(population=4)
    _seed_population(s, 4)
    snap = s.snapshot()
    json.dumps(snap)
    assert snap["population"] == 4
    assert [m["lineage"] for m in snap["members"]] \
        == [m.lineage for m in s._members.values()]
    row = s.stats()[0]
    assert row["members"] == 4 and row["best"] == 3.0


# ---------------------------------------------------------------------------
# the driver: all three schedulers end-to-end, exactly-once under races
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["asha", "hyperband", "pbt"])
def test_driver_runs_scheduler_exactly_once(kind, tmp_path):
    """Every scheduler through the same driver, with preemption on and
    enough parallelism that decide()-preempts race completions: every
    (lineage-or-key, rung) is recorded at most once, spend covers the
    budget, and PBT provenance lands in history."""
    obj = ForkCapable()
    mf = MultiFidelityConfig(enabled=True, scheduler=kind,
                             min_fidelity=1 / 9, eta=3.0, preempt=True)
    mf.pbt.population = 4
    mf.pbt.step_fidelity = 0.5
    t = Tuner(obj, make_space(), TunerConfig(
        algorithm="random", budget=12, seed=5, verbose=False, parallelism=4,
        checkpoint_path=str(tmp_path / "ckpt.json"), multi_fidelity=mf))
    h = t.run()
    t.close()
    assert len(h) > 0
    # trial identity: PBT's is its lineage+step, the ladders' is
    # (point, rung) — lineage there is the bracket tag, shared
    keys = [(e.lineage, t.space.key(e.point), e.rung)
            for e in h.evals if not e.meta.get("preempted")]
    assert len(keys) == len(set(keys))
    # spend never exceeds budget by more than the in-flight overhang
    # (it may fall short: the finite space can exhaust the engine first)
    spend = sum(e.fidelity for e in h.evals)
    assert 0 < spend <= 12 + 4
    if kind == "pbt":
        assert all(e.lineage for e in h.evals)
        assert any(e.meta.get("fork_state") for e in h.evals)
        # forked lineages name their parent in provenance
        snap = t.rung_scheduler.snapshot()
        assert any(m["parent"] for m in snap["members"]) \
            or t.rung_scheduler.n_forks == 0


@pytest.mark.parametrize("kind", ["asha", "hyperband", "pbt"])
def test_driver_resume_replays_scheduler_state(kind, tmp_path):
    """Crash after a short run, resume with a larger budget: nothing the
    checkpoint holds is re-measured at the same (lineage/key, rung), and
    the resumed scheduler starts from the replayed state."""
    def mk(budget):
        mf = MultiFidelityConfig(enabled=True, scheduler=kind,
                                 min_fidelity=1 / 9, eta=3.0)
        mf.pbt.population = 4
        mf.pbt.step_fidelity = 0.5
        return TunerConfig(algorithm="random", budget=budget, seed=5,
                           verbose=False, parallelism=2,
                           checkpoint_path=str(tmp_path / "ckpt.json"),
                           multi_fidelity=mf)

    t1 = Tuner(ForkCapable(), make_space(), mk(4))
    h1 = t1.run()
    t1.close()
    assert len(h1) > 0

    obj2 = ForkCapable()
    t2 = Tuner(obj2, make_space(), mk(9))
    assert len(t2.history) == len(h1)  # the whole checkpoint replayed
    h2 = t2.run()
    t2.close()
    keys = [(e.lineage, t2.space.key(e.point), e.rung)
            for e in h2.evals if not e.meta.get("preempted")]
    assert len(keys) == len(set(keys))
    assert len(h2) > len(h1)
    if kind == "pbt":
        # replayed lineages kept their step counters: new steps continue
        # past the checkpoint instead of restarting at 0
        by_lineage = {}
        for e in h2.evals:
            by_lineage.setdefault(e.lineage, []).append(e.rung)
        assert any(max(rungs) >= 1 for rungs in by_lineage.values())
