"""Cost-model units: analytic traffic, kernel credit, backend config."""

import pytest

from repro.configs import get_config, get_shape
from repro.tuning.cost_model import (
    analytic_hbm_traffic,
    kernel_traffic_bytes,
    model_flops,
    tokens_per_step,
)
from repro.tuning.hlo_analysis import traffic_analysis
from repro.tuning.parameters import BASELINE, BackendConfig, config_from_point


def test_backend_config_mesh_factorization():
    bc = BackendConfig(log2_dp=4)
    assert bc.dp() == 16 and bc.tp() == 16 and bc.dp() * bc.tp() == 256
    bc2 = BackendConfig(log2_dp=8)
    assert bc2.dp() == 256 and bc2.tp() == 1
    bc3 = BackendConfig(log2_dp=0)
    assert bc3.dp() == 1 and bc3.tp() == 256


def test_config_from_point_roundtrip():
    pt = {"log2_dp": 2, "remat": "names", "microbatches": 4, "block_q": 256}
    bc = config_from_point(pt)
    assert bc.log2_dp == 2 and bc.remat == "names" and bc.microbatches == 4
    assert bc.block_q == 256
    # a stray key (typo'd search-space dim) must be loud, not silently
    # dropped; allow_extra is the explicit opt-out for keys a harness
    # handles outside BackendConfig
    with pytest.raises(ValueError, match="not_a_field"):
        config_from_point(dict(pt, not_a_field=1))
    bc2 = config_from_point(dict(pt, not_a_field=1),
                            allow_extra=("not_a_field",))
    assert bc2 == bc


def test_model_flops_conventions():
    cfg = get_config("qwen2-0.5b")
    n = cfg.param_counts()["active"]
    tr = get_shape("train_4k")
    de = get_shape("decode_32k")
    assert model_flops(cfg, tr, n) == 6.0 * n * tr.global_batch * tr.seq_len
    assert model_flops(cfg, de, n) == 2.0 * n * de.global_batch
    assert tokens_per_step(de) == de.global_batch


def test_moe_active_params_lt_total():
    cfg = get_config("qwen3-moe-30b-a3b")
    pc = cfg.param_counts()
    assert pc["active"] < pc["total"] / 3  # 8 of 128 experts active
    dense = get_config("deepseek-coder-33b").param_counts()
    assert dense["active"] == dense["total"]
    # totals near the nameplate sizes
    assert 25e9 < cfg.param_counts()["total"] < 36e9
    assert 28e9 < dense["total"] < 38e9


def test_param_counts_sane_for_all_archs():
    expected = {
        "jamba-v0.1-52b": (45e9, 60e9),
        "grok-1-314b": (250e9, 360e9),
        "minicpm3-4b": (3e9, 6e9),
        "rwkv6-3b": (2.5e9, 4.5e9),
        "whisper-base": (0.05e9, 0.12e9),
        "h2o-danube-1.8b": (1.4e9, 2.4e9),
        "internvl2-26b": (17e9, 26e9),  # LM backbone only (vision stubbed)
        "qwen2-0.5b": (0.4e9, 0.8e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_counts()["total"]
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_analytic_traffic_scales_with_shape():
    cfg = get_config("qwen2-0.5b")
    tr = analytic_hbm_traffic(cfg, get_shape("train_4k"), BASELINE, 256)
    de = analytic_hbm_traffic(cfg, get_shape("decode_32k"), BASELINE, 256)
    assert tr["total"] > de["total"]  # train moves far more bytes
    assert de["params"] > de["activations"]  # decode is weight/cache-bound
    for v in tr.values():
        assert v >= 0


def test_kernel_credit_decode_scales_with_cache():
    cfg = get_config("deepseek-coder-33b")
    k32 = kernel_traffic_bytes(cfg, get_shape("decode_32k"), BASELINE, 256)
    assert k32 > 0
    cfg_swa = get_config("h2o-danube-1.8b")
    k_long = kernel_traffic_bytes(cfg_swa, get_shape("long_500k"), BASELINE, 256)
    k_dec = kernel_traffic_bytes(cfg_swa, get_shape("decode_32k"), BASELINE, 256)
    # SWA bounds the cache: long context costs the same per token
    assert k_long <= k_dec * 1.01


def test_traffic_analysis_excludes_tagged_ops():
    hlo = '''
ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %m1 = f32[64,64]{1,0} multiply(%p, %p), metadata={op_name="jit(f)/krnl_flash_attn/mul"}
  %m2 = f32[64,64]{1,0} multiply(%m1, %m1), metadata={op_name="jit(f)/other/mul"}
  ROOT %c = f32[64,64]{1,0} copy(%m2)
}
'''
    st = traffic_analysis(hlo)
    per_op = 64 * 64 * 4
    assert st.excluded_bytes == 3 * per_op  # m1: out + 2 operands
    assert st.included_bytes == 3 * per_op + 2 * per_op  # m2 + copy
    assert "krnl_flash_attn" in st.excluded_by_tag
