"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting shapes and no NaNs (assignment req)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import CPU_TEST, build_model
from repro.models.params import split_params
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step

pytestmark = pytest.mark.slow  # per-arch forward/train-step compiles are minutes of XLA work

ARCHS = list_archs()


def _batch(cfg, B=2, S=24, train=True):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if train:
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.encoder_layers:
        batch["encoder_embeds"] = jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, train=False)
    logits, aux, _ = model.apply(params, batch, rt=CPU_TEST)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    opt_cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=1)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg, CPU_TEST))
    batch = _batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"])), f"{arch}: NaN grads"
    # at least some parameter moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2))
    )
    assert moved, f"{arch}: update was a no-op"


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "jamba-v0.1-52b", "rwkv6-3b",
                                  "minicpm3-4b", "whisper-base",
                                  "h2o-danube-1.8b"])
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must match a full forward (bf16-cache tol)."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg)  # capacity handled via rt below
    model = build_model(cfg)
    rt = dataclasses.replace(CPU_TEST, moe_capacity_factor=16.0)
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))
    B, S = 2, 24
    batch = _batch(cfg, B, S, train=False)
    cache, _ = split_params(model.init_cache(B, 32))
    lg_pre, _, cache = model.apply(params, batch, rt=rt, mode="prefill",
                                   cache=cache)
    lg_full, _, _ = model.apply(params, batch, rt=rt)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]),
                               np.asarray(lg_full[:, -1]), atol=1e-4)
    tok = jnp.argmax(lg_pre[:, 0], -1)[:, None].astype(jnp.int32)
    lg_dec, cache = model.decode_step(params, tok, cache, rt=rt)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    lg_full2, _, _ = model.apply(params, b2, rt=rt)
    ref = np.asarray(lg_full2[:, -1])
    err = np.abs(np.asarray(lg_dec[:, 0]) - ref).max()
    assert err / (np.abs(ref).max() + 1e-9) < 2e-2, f"{arch}: decode diverges"


def test_sliding_window_cache_is_bounded():
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    cache, _ = split_params(model.init_cache(2, 100))
    # every attn cache buffer seq dim is capped at the window
    shapes = [v.shape for v in jax.tree_util.tree_leaves(cache)
              if hasattr(v, "shape") and len(getattr(v, "shape", ())) == 5]
    assert shapes, "no stacked kv cache found"
    for s in shapes:
        assert s[2] <= cfg.sliding_window


def test_mla_cache_is_latent_sized():
    cfg = get_config("minicpm3-4b").reduced()
    model = build_model(cfg)
    cache, _ = split_params(model.init_cache(2, 64))
    leaves = {jax.tree_util.keystr(p): v.shape
              for p, v in jax.tree_util.tree_flatten_with_path(cache)[0]}
    ckv = [s for k, s in leaves.items() if "ckv" in k]
    assert ckv and ckv[0][-1] == cfg.mla.kv_lora_rank  # compressed, not H*dh


def test_layer_period_plans():
    jamba = get_config("jamba-v0.1-52b")
    assert jamba.layer_period() == 8
    plan = jamba.layer_plan()
    assert sum(1 for m, _ in plan if m == "attn") == 4  # 1:7 ratio over 32
    assert sum(1 for _, f in plan if f == "moe") == 16  # MoE every 2
    assert get_config("qwen2-0.5b").layer_period() == 1
