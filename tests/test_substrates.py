"""Optimizer, data pipeline, checkpointing, serving, fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.optim.optimizer import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    global_norm,
    lr_schedule,
)
from repro.runtime.fault_tolerance import (
    ElasticPlan,
    FailureInjector,
    StragglerDetector,
    WorkerFailure,
)

pytestmark = pytest.mark.slow  # optimizer/pipeline integration runs


# --- optimizer ---------------------------------------------------------------


def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array(5.0)}
    target = {"w": jnp.array([1.0, 1.0, 1.0]), "b": jnp.array(0.0)}

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    return params, loss


@pytest.mark.parametrize("state_dtype,factored", [("f32", False),
                                                  ("bf16", False),
                                                  ("f32", True)])
def test_adamw_converges(state_dtype, factored):
    params, loss = _quad_problem()
    cfg = OptimizerConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=300, state_dtype=state_dtype,
                          factored=factored)
    state = adamw_init(params, cfg)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_factored_second_moment_is_smaller():
    params = {"w": jnp.zeros((64, 128))}
    full = adamw_init(params, OptimizerConfig(factored=False))
    fact = adamw_init(params, OptimizerConfig(factored=True))
    n_full = sum(x.size for x in jax.tree_util.tree_leaves(full["v"]))
    n_fact = sum(x.size for x in jax.tree_util.tree_leaves(fact["v"]))
    assert n_fact == 64 + 128 and n_full == 64 * 128


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = OptimizerConfig(grad_clip=1.0, warmup_steps=0)
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(huge, state, params, cfg)
    assert float(metrics["clip"]) < 1e-5


def test_lr_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup
    assert abs(lrs[10] - 1.0) < 0.02  # peak
    assert abs(lrs[100] - 0.1) < 0.02  # floor


# --- data pipeline -----------------------------------------------------------


def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=7)
    src = SyntheticTokens(cfg)
    b1 = src.batch_at(3)
    b2 = src.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards tile the global batch
    parts = [src.batch_at(3, shard=i, num_shards=4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # targets are next-token shifted
    assert b1["targets"].shape == b1["tokens"].shape
    assert (b1["tokens"] < 100).all() and (b1["tokens"] >= 0).all()


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=97, seq_len=64, global_batch=16, noise=0.1)
    b = SyntheticTokens(cfg).batch_at(0)
    pred = (b["tokens"] * 3 + 7) % 97
    agree = (pred == b["targets"]).mean()
    assert agree > 0.8  # bigram rule holds away from noise


def test_prefetcher_orders_batches():
    src = SyntheticTokens(DataConfig(vocab_size=50, seq_len=8, global_batch=2))
    pf = Prefetcher(src, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(5)]
        assert steps == [0, 1, 2, 3, 4]
    finally:
        pf.close()


# --- checkpointing -----------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                   "scale": jnp.asarray(rng.standard_normal(8), jnp.float32)},
        "opt": {"m": jnp.zeros((4, 8)), "count": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path / "ck", async_save=False)
    tree = _tree()
    ck.save(10, tree, metadata={"config": "t"}, metric=1.0)
    restored, meta = ck.restore(None, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert meta["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last_and_best(tmp_path):
    ck = Checkpointer(tmp_path / "ck", keep_last=2, keep_best=1,
                      async_save=False)
    tree = _tree()
    for step, metric in [(1, 5.0), (2, 1.0), (3, 2.0), (4, 0.5)]:
        ck.save(step, tree, metric=metric)
    steps = ck.steps()
    assert 3 in steps and 4 in steps  # last two
    assert 1 in steps  # best metric protected
    assert 2 not in steps  # gc'd


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path / "ck", async_save=False)
    tree = _tree()
    ck.save(1, tree)
    blob = next((tmp_path / "ck").glob("step_*/shard_000.npz"))
    blob.write_bytes(blob.read_bytes()[:-4] + b"beef")
    with pytest.raises(IOError, match="corrupt"):
        ck.restore(None, tree)


def test_checkpoint_async_completes(tmp_path):
    ck = Checkpointer(tmp_path / "ck", async_save=True)
    ck.save(5, _tree())
    ck.wait()
    assert ck.latest_step() == 5


# --- fault tolerance ----------------------------------------------------------


def test_straggler_detector_flags_sustained_outliers():
    det = StragglerDetector(warmup=5, sustained=3, z_threshold=4.0)
    flagged = []
    for i in range(30):
        t = 1.0 + 0.01 * np.sin(i)
        flagged.append(det.update(t))
    assert not any(flagged)
    res = [det.update(10.0) for _ in range(3)]
    assert res[-1] is True  # sustained straggle fires


def test_failure_injector_fires_once():
    inj = FailureInjector(at_steps=[5])
    with pytest.raises(WorkerFailure):
        inj.check(5)
    inj.check(5)  # second pass: no raise (fired once)


def test_elastic_plan_shrinks_dp():
    plan = ElasticPlan.after_failure(dp=16, tp=16, lost_chips=16)
    assert plan.new_dp == 8 and plan.tp == 16
    plan2 = ElasticPlan.after_failure(dp=4, tp=2, lost_chips=1)
    assert plan2.new_dp == 2


def test_global_norm():
    n = global_norm({"a": jnp.array([3.0]), "b": jnp.array([4.0])})
    assert abs(float(n) - 5.0) < 1e-6
