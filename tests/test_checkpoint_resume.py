"""Checkpoint round-trip fault tolerance (the tuning service's crash
contract): a Tuner snapshotted mid-run and restored must equal a
never-crashed run minus only the evaluations that were in flight at the
kill — nothing recorded is lost, nothing is double-measured, and the
multi-fidelity rung scheduler's replayed state keeps promoted survivors
promoted.
"""
import json
import math
import pathlib

import pytest

from repro.checkpoint.checkpointer import JsonCheckpointer
from repro.core import (CatDim, ExecutorConfig, History, IntDim,
                        MultiFidelityConfig, Observation, SearchSpace, Tuner,
                        TunerConfig)
from repro.tuning.fidelity import RungScheduler
from repro.tuning.objective import CountingEvaluator, Evaluator


def make_space() -> SearchSpace:
    return SearchSpace([IntDim("inter_op", 1, 4),
                        IntDim("intra_op", 0, 30, 10),
                        CatDim("build", (1, 2))])


def value_of(p):
    return float(3.0 * p["inter_op"] + 0.2 * p["intra_op"] + 7.0 * p["build"])


class FidelityObjective(Evaluator):
    supports_fidelity = True

    def __init__(self):
        self.calls = []  # (key, fidelity) per real measurement

    def __call__(self, p, fidelity=None):
        f = 1.0 if fidelity is None else float(fidelity)
        self.calls.append(((p["inter_op"], p["intra_op"], p["build"]), f))
        wiggle = ((p["inter_op"] * 13 + p["intra_op"] * 7) % 5 - 2) / 3.0
        return value_of(p) + (1.0 - f) * wiggle, {"cost_seconds": 0.01 * f}


def cfg(tmp_path, **kw):
    kw.setdefault("algorithm", "exhaustive")
    kw.setdefault("verbose", False)
    kw.setdefault("checkpoint_path", str(tmp_path / "ckpt.json"))
    return TunerConfig(**kw)


# ---------------------------------------------------------------------------
# Tuner resume equality
# ---------------------------------------------------------------------------

def test_resume_equals_uninterrupted_run(tmp_path):
    """Crash after k evals + resume == one uninterrupted run (exhaustive
    engine: fully determined by history, so equality is exact)."""
    space = make_space()
    budget = 12

    straight = Tuner(value_of, space,
                     cfg(tmp_path / "a", budget=budget)).run()

    # crashed run: stop at k, then a NEW tuner resumes from the checkpoint
    k = 5
    Tuner(value_of, space, cfg(tmp_path / "b", budget=k)).run()
    resumed = Tuner(value_of, space,
                    cfg(tmp_path / "b", budget=budget)).run()

    assert [(e.point, e.value) for e in resumed.evals] \
        == [(e.point, e.value) for e in straight.evals]


def test_resume_measures_only_the_lost_suffix(tmp_path):
    """A resumed run re-measures nothing the checkpoint already holds."""
    space = make_space()
    Tuner(value_of, space, cfg(tmp_path, budget=6)).run()
    prefix = {tuple(sorted(e.point.items()))
              for e in History.load(tmp_path / "ckpt.json", space).evals}

    counting = CountingEvaluator(value_of)
    resumed = Tuner(counting, space, cfg(tmp_path, budget=10)).run()
    assert len(resumed) == 10
    assert counting.calls == 10 - len(prefix)


def test_resume_drops_only_inflight(tmp_path):
    """Simulated SIGKILL mid-measurement: the checkpoint holds completed
    evaluations only, so a resumed run loses exactly the in-flight one
    (it is re-measured, not double-recorded)."""
    space = make_space()
    Tuner(value_of, space, cfg(tmp_path, budget=7)).run()
    path = tmp_path / "ckpt.json"
    evals = json.loads(path.read_text())
    lost = evals.pop()  # the in-flight eval a crash would not have saved
    path.write_text(json.dumps(evals))

    counting = CountingEvaluator(value_of)
    resumed = Tuner(counting, space, cfg(tmp_path, budget=7)).run()
    assert len(resumed) == 7
    # the lost point was measured again, and nothing else was
    assert counting.calls == 1
    measured = [e.point for e in resumed.evals if not e.meta.get("memoized")]
    assert lost["point"] in measured
    # no point appears twice in the resumed record
    keys = [space.key(e.point) for e in resumed.evals]
    assert len(keys) == len(set(keys))


def test_resume_replays_through_tell_as_observations(tmp_path):
    """The resume path feeds the engine Observation records (the v2 tell
    API) — fidelities and costs survive the round trip."""
    space = make_space()
    h = History(space)
    h.add_observations([
        Observation(point={"inter_op": 1, "intra_op": 0, "build": 1},
                    value=1.5, cost_seconds=0.25, fidelity=0.5),
        Observation(point={"inter_op": 2, "intra_op": 10, "build": 2},
                    value=3.0, cost_seconds=1.0),
    ])
    path = tmp_path / "h.json"
    h.save(path)
    loaded = History.load(path, space)
    obs = loaded.observations()
    assert [(o.value, o.cost_seconds, o.fidelity) for o in obs] \
        == [(1.5, 0.25, 0.5), (3.0, 1.0, 1.0)]


# ---------------------------------------------------------------------------
# multi-fidelity: rung state restore
# ---------------------------------------------------------------------------

def _rung_state(sched):
    """Comparable rung state: results + promotion marks (not counters —
    replay deliberately leaves this-run scheduling counters at zero)."""
    return [(sorted(r.results), sorted(r.promoted)) for r in sched.rungs]


def test_rungscheduler_replay_reconstructs_state():
    a = RungScheduler(eta=2.0, min_fidelity=0.25)
    pts = {k: {"x": i} for i, k in enumerate("abcd")}
    for k, v in [("a", 10.0), ("b", 4.0), ("c", 8.0), ("d", 1.0)]:
        a.on_result((k,), pts[k], v, 0)
    promo = a.next_promotion()
    assert promo is not None
    point, rung = promo
    a.on_result(("a",), point, 10.5, rung)

    # replay from the trace a checkpoint would hold (key, point, value,
    # fidelity) — completion order, fidelities as recorded
    b = RungScheduler(eta=2.0, min_fidelity=0.25)
    for k, v in [("a", 10.0), ("b", 4.0), ("c", 8.0), ("d", 1.0)]:
        b.replay((k,), pts[k], v, a.fidelity(0))
    b.replay(("a",), pts["a"], 10.5, a.fidelity(rung))

    assert _rung_state(a) == _rung_state(b)
    # the replayed survivor stays promoted: it must NOT be promotable again
    nxt = b.next_promotion()
    assert nxt is None or b.rungs[nxt[1] - 1].promoted != {("a",)}


def test_rungscheduler_replay_marks_source_rung_promoted():
    """A rung-r result replays as promoted-out-of-rung-(r-1); without the
    mark a resumed run would re-promote (and re-measure) it."""
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    s.replay(("k",), {"x": 1}, 5.0, s.fidelity(1))
    assert ("k",) in s.rungs[0].promoted
    assert s.rungs[1].results == [(("k",), 5.0)]


def test_rungscheduler_replay_dedupes_preemption_race_records():
    """Regression: a checkpoint written around a preemption race can
    hold BOTH a preempted placeholder and a completed record for the
    same (key, rung).  Replay used to charge budget for both and rank
    the key twice; now the preempted record charges 0 and skips, and a
    duplicate completion charges 0 and is not re-ranked."""
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    f0 = s.fidelity(0)
    # the preempted placeholder measured nothing: no charge, no state
    assert s.replay(("k",), {"x": 1}, 0.0, f0,
                    meta={"preempted": True}) == 0.0
    assert s.rungs[0].results == []
    # the completed record charges once...
    assert s.replay(("k",), {"x": 1}, 5.0, f0) == pytest.approx(f0)
    # ...and its duplicate (same key, same rung) charges nothing
    assert s.replay(("k",), {"x": 1}, 5.0, f0) == 0.0
    assert s.rungs[0].results == [(("k",), 5.0)]
    assert s.rungs[0].n_completed == 1


def test_rungscheduler_replay_dedupe_is_per_rung_not_per_key():
    """The same key legitimately completes once per rung of the ladder;
    only same-rung duplicates are checkpoint artifacts."""
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    charged = [s.replay(("k",), {"x": 1}, 5.0, s.fidelity(r))
               for r in range(s.n_rungs)]
    assert charged == pytest.approx([s.fidelity(r)
                                     for r in range(s.n_rungs)])
    assert [r.n_completed for r in s.rungs] == [1] * s.n_rungs


def test_rungscheduler_snapshot_is_jsonable_and_complete():
    s = RungScheduler(eta=3.0, min_fidelity=0.1)
    s.on_started(("a", 1), {"x": 0, "y": 1}, 0)
    s.on_result(("a", 1), {"x": 0, "y": 1}, 2.0, 0)
    snap = s.snapshot()
    json.dumps(snap)  # wire-safe
    assert snap[0]["completed"] == 1
    assert snap[0]["results"] == [[["a", 1], 2.0]]


def test_multi_fidelity_resume_no_remeasure_and_spend_carries(tmp_path):
    """Resuming a multi-fidelity run replays rung state AND budget spend:
    checkpointed (point, fidelity) completions are never measured again,
    and the resumed run finishes the remaining budget only."""
    space = make_space()

    def mf_cfg(budget):
        return cfg(tmp_path, algorithm="random", budget=budget,
                   multi_fidelity=MultiFidelityConfig(
                       enabled=True, eta=2.0, min_fidelity=0.5),
                   executor=ExecutorConfig(parallelism=2))

    first = FidelityObjective()
    t1 = Tuner(first, space, mf_cfg(budget=4))
    h1 = t1.run()
    t1.close()
    assert len(h1) > 0
    spend1 = sum(e.fidelity for e in h1.evals)

    second = FidelityObjective()
    t2 = Tuner(second, space, mf_cfg(budget=8))
    pre = len(t2.history)
    assert pre == len(h1)  # the whole checkpoint replayed
    h2 = t2.run()
    t2.close()

    # nothing the checkpoint already held was re-measured
    replayed = {(space.key(e.point), round(e.fidelity, 9))
                for e in h1.evals}
    remeasured = [c for c in second.calls
                  if (space.key({"inter_op": c[0][0], "intra_op": c[0][1],
                                 "build": c[0][2]}), round(c[1], 9))
                  in replayed]
    assert remeasured == []
    # budget accounting resumed, not restarted: total spend covers the
    # full budget but the new run paid only the difference
    spend2 = sum(e.fidelity for e in h2.evals)
    assert spend2 >= 8.0 - 1.0  # reached (within one final grant)
    new_spend = sum(e.fidelity for e in h2.evals[pre:])
    assert new_spend == pytest.approx(spend2 - spend1)


# ---------------------------------------------------------------------------
# JsonCheckpointer (the service's job-document store)
# ---------------------------------------------------------------------------

def test_json_checkpointer_roundtrip_and_retention(tmp_path):
    c = JsonCheckpointer(tmp_path, keep_last=2)
    for i in range(5):
        c.save({"i": i})
    assert c.load() == {"i": 4}
    assert len(list(pathlib.Path(tmp_path).glob("snap_*.json"))) == 2


def test_json_checkpointer_survives_torn_write(tmp_path):
    c = JsonCheckpointer(tmp_path, keep_last=3)
    c.save({"i": 0})
    c.save({"i": 1})
    snaps = sorted(pathlib.Path(tmp_path).glob("snap_*.json"))
    snaps[-1].write_text(snaps[-1].read_text()[:-25])  # the crash tore it
    assert c.load() == {"i": 0}


def test_json_checkpointer_empty_dir_loads_none(tmp_path):
    assert JsonCheckpointer(tmp_path).load() is None


# ---------------------------------------------------------------------------
# cooperative stop (the service's cancel_job path)
# ---------------------------------------------------------------------------

def test_request_stop_preserves_recorded_history(tmp_path):
    space = make_space()
    tuner = Tuner(value_of, space, cfg(tmp_path, budget=1000))
    tuner.request_stop()  # before run: exits at the first loop check
    h = tuner.run()
    assert len(h) == 0 or len(h) < 1000
    assert math.isfinite(sum(e.value for e in h.evals) + 0.0)
    # the stop is resumable: a fresh tuner picks the checkpoint up
    again = Tuner(value_of, space, cfg(tmp_path, budget=5)).run()
    assert len(again) == 5
