"""Sharding rules + HLO analysis units, and a subprocess mini dry-run."""
import os
import subprocess
import sys

import pytest

from repro.tuning.hlo_analysis import (
    collect_collective_stats,
    shape_bytes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shape_bytes():
    assert shape_bytes("f32[8,256]{1,0}") == 8 * 256 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert shape_bytes("token[]") == 0


HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
  %ar = f32[8,64]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[8,64]) tuple(%i, %ar)
}

ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %ag = f32[16,64]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[16,64]{1,0} copy(%ag)
}
"""


def test_collective_stats_scales_while_bodies():
    stats = collect_collective_stats(HLO_SAMPLE)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.count_by_kind["all-reduce"] == 4  # trip count applied
    assert stats.bytes_by_kind["all-reduce"] == 4 * 8 * 64 * 4
    assert stats.bytes_by_kind["all-gather"] == 16 * 64 * 4


def test_sharding_rules_divisibility():
    """Rules drop axes whose dims don't divide the mesh axis size."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import ShardingRules

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    rules = ShardingRules.__new__(ShardingRules)
    rules.mesh = FakeMesh()
    rules.style = "fsdp_tp"
    from repro.distributed.sharding import make_rules

    rules.rules = make_rules("fsdp_tp", multi_pod=False)
    # 14 heads on 16-way model axis: dropped; ff 4864 divides: kept
    spec = rules.spec_for(("embed", "heads", None), (896, 14, 64))
    assert spec == P("data")  # heads dropped, embed kept (fsdp)
    spec2 = rules.spec_for(("embed", "ff"), (896, 4864))
    assert spec2 == P("data", "model")
    # conflicting axes: first dim wins the mesh axis
    spec3 = rules.spec_for(("ff", "ff"), (4864, 4864))
    assert spec3 == P("model")


def test_backend_space_adapts_per_arch():
    """Attention-free archs drop attention tiles (paper's per-model ranges)."""
    from repro.configs import get_config
    from repro.tuning.parameters import backend_space

    rwkv_dims = {d["name"] for d in backend_space(get_config("rwkv6-3b"))}
    dense_dims = {d["name"] for d in backend_space(get_config("qwen2-0.5b"))}
    moe_dims = {d["name"] for d in backend_space(get_config("qwen3-moe-30b-a3b"))}
    assert "block_q" not in rwkv_dims and "scan_chunk" in rwkv_dims
    assert "block_q" in dense_dims and "capacity_factor" not in dense_dims
    assert "capacity_factor" in moe_dims


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Real lower+compile through the dryrun CLI on a tiny placeholder mesh."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--chips-per-pod", "16", "--log2-dp", "2"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_roofline_math():
    from repro.tuning.cost_model import Roofline

    r = Roofline(flops_per_device=197e12 * 0.01, bytes_per_device=819e9 * 0.02,
                 collective_bytes=50e9 * 0.005, tokens_per_step=1000,
                 chips=256, model_flops=197e12 * 0.01 * 256 * 0.5,
                 memory_per_device=8e9)
    assert r.bottleneck == "memory"
    assert abs(r.est_step_time - 0.02) < 1e-9
    assert abs(r.throughput - 1000 / 0.02) < 1e-6
    assert r.fits_hbm is True
    assert abs(r.roofline_fraction - 0.5) < 1e-9
    assert abs(r.mfu - 0.25) < 1e-9
