"""Transfer learning across tuning jobs: corpus storage + similarity,
surrogate warm-starts, the negative-transfer guard, the candidate
pre-filter, and the strict-serialization fix for persisted grid keys.

The no-corpus golden traces are pinned in test_executor.py /
test_async_loop.py; here the complementary invariant is pinned: a
*configured but unhelpful* corpus (empty, or beyond ``max_distance``)
must leave the tuning trace byte-identical too.
"""
import json
import math
import pathlib

import numpy as np
import pytest

from repro.core import (History, Observation, SearchSpace, TransferConfig,
                        Tuner, TunerConfig)
from repro.core.bayesopt import BayesOpt, TransferPrior
from repro.tuning.corpus import (TuningCorpus, prediction_agreement,
                                 space_fingerprint, task_features,
                                 workload_distance)
from repro.tuning.executor import (EvalResult, EvaluationExecutor, MemoCache,
                                   _store_key, memo_key)
from repro.tuning.objective import Evaluator

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "ask_tell_traces.json")
    .read_text())


def golden_space() -> SearchSpace:
    return SearchSpace.from_dicts(GOLDEN["space"])


def golden_objective(p):
    a, b, c = p["inter_op"], p["intra_op"], p["build"]
    return float(50.0 * pow(2.718281828, -((a - 11) / 5.0) ** 2)
                 + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)


class FeaturedObjective(Evaluator):
    """Synthetic workload with declared task features."""

    def __init__(self, features, value_fn=golden_objective):
        self.features = dict(features)
        self.value_fn = value_fn
        self.calls = 0

    def task_features(self):
        return dict(self.features)

    def __call__(self, p, fidelity=None):
        self.calls += 1
        return self.value_fn(p), {"cost_seconds": 0.01}


def _populate(corpus_path, job_id, features, points_values,
              space=None, objective=None):
    space = space or golden_space()
    corpus = TuningCorpus(corpus_path, job_id=job_id)
    corpus.describe_job(objective or FeaturedObjective(features), space)
    for p, v in points_values:
        corpus.add(p, v, cost_seconds=0.02)
    corpus.flush()
    return corpus


# ---------------------------------------------------------------------------
# similarity layer
# ---------------------------------------------------------------------------

def test_workload_distance_properties():
    a = {"flops": 1e12, "bytes": 4e9}
    assert workload_distance(a, a) == 0.0
    assert workload_distance({}, {}) == 0.0  # same space, nothing known
    near = {"flops": 1.1e12, "bytes": 4.4e9}
    far = {"flops": 1e14, "bytes": 4e11}
    assert workload_distance(a, near) < workload_distance(a, far)
    # a feature only one side declares counts as maximally different
    assert workload_distance(a, {"flops": 1e12}) == pytest.approx(0.5)
    # symmetric
    assert workload_distance(a, far) == pytest.approx(
        workload_distance(far, a))


def test_task_features_coercion_and_fallbacks():
    assert task_features(lambda p: 1.0) == {}  # plain callables: no hook
    obj = FeaturedObjective({"flops": 5, "bad": "nan-ish",
                             "inf": float("inf")})
    obj.features["bad"] = float("nan")
    feats = task_features(obj)
    assert feats == {"flops": 5.0}  # non-finite / non-numeric dropped

    class Exploding:
        def task_features(self):
            raise RuntimeError("harness not built yet")

    assert task_features(Exploding()) == {}


def test_prediction_agreement_degenerate_cases():
    assert prediction_agreement([1.0], [2.0]) is None  # < 2 pairs
    assert prediction_agreement([1, 2], [5, 5]) is None  # constant side
    assert prediction_agreement([1, 2, 3], [1, 2]) is None  # mismatch
    assert prediction_agreement([1, 2, 3], [2, 4, 6]) == pytest.approx(1.0)
    assert prediction_agreement([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)


# ---------------------------------------------------------------------------
# corpus storage + neighbor selection
# ---------------------------------------------------------------------------

def test_corpus_roundtrip_persists_across_instances(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(0), 4)
    _populate(path, "writer", feats, [(p, golden_objective(p)) for p in pts])

    reader = TuningCorpus(path, job_id="reader")
    recs = reader.records()
    assert len(recs) == 4
    for rec in recs:
        assert rec["workload"]["job_id"] == "writer"
        assert rec["workload"]["space"] == space_fingerprint(space)
        assert rec["cost_seconds"] == pytest.approx(0.02)
    rows = reader.prior_observations(space, feats)
    assert len(rows) == 4
    assert all(r["distance"] == 0.0 for r in rows)


def test_corpus_rerun_with_same_job_id_appends_not_overwrites(tmp_path):
    """Crash-resume reuses job.job_id and launch/tune.py derives
    deterministic job_ids, so two *processes* writing under the same
    job_id must union their records — the per-process key nonce keeps a
    re-run from overwriting the earlier run at the same key indices."""
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    p = {"inter_op": 3, "intra_op": 10, "build": 1}
    # two corpus instances = two processes resuming the same job
    _populate(path, "job-A", feats, [(p, 1.0), (p, 2.0)])
    _populate(path, "job-A", feats, [(p, 3.0), (p, 4.0)])
    recs = TuningCorpus(path, job_id="reader").records()
    assert sorted(r["value"] for r in recs) == [1.0, 2.0, 3.0, 4.0]


def test_corpus_add_requires_descriptor(tmp_path):
    corpus = TuningCorpus(tmp_path / "c.json", job_id="j")
    with pytest.raises(RuntimeError, match="describe_job"):
        corpus.add({"inter_op": 1, "intra_op": 0, "build": 1}, 1.0)


def test_neighbors_filter_space_distance_and_own_job(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    p = {"inter_op": 3, "intra_op": 10, "build": 1}
    base = {"flops": 1e12, "bytes": 4e9}
    _populate(path, "near", base, [(p, 1.0)])
    _populate(path, "far", {"flops": 1e15, "bytes": 4e12}, [(p, 2.0)])
    other_space = SearchSpace.from_dicts(
        [{"type": "int", "name": "inter_op", "min": 1, "max": 4}])
    _populate(path, "other-space", base,
              [({"inter_op": 2}, 3.0)], space=other_space)

    reader = TuningCorpus(path, job_id="me")
    near = reader.neighbors(space, base)
    assert [g["job_id"] for g in near] == ["near"]  # far + other-space cut
    assert near[0]["distance"] == 0.0
    # a job never sees itself as a neighbor (no self-transfer)
    assert reader.neighbors(space, base, exclude_job="near") == []


def test_prior_observations_skip_failures_and_stale_points(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    good = {"inter_op": 3, "intra_op": 10, "build": 1}
    stale = {"inter_op": 99, "intra_op": 10, "build": 1}  # not on the grid
    _populate(path, "donor", feats,
              [(good, 5.0), (good, float("-inf")), (stale, 9.0)])
    rows = TuningCorpus(path, job_id="me").prior_observations(space, feats)
    assert [r["value"] for r in rows] == [5.0]


def test_prior_observations_quota_keeps_value_spread(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(1), 30)
    _populate(path, "donor", feats,
              [(p, float(i)) for i, p in enumerate(pts)])
    rows = TuningCorpus(path, job_id="me").prior_observations(
        space, feats, max_rows=8)
    values = sorted(r["value"] for r in rows)
    assert len(rows) <= 8
    assert values[0] == 0.0 and values[-1] == 29.0  # floor and peak kept


# ---------------------------------------------------------------------------
# TransferPrior + engine warm-start
# ---------------------------------------------------------------------------

def _prior_from(space, points_values, distance=0.1):
    rows = [{"point": p, "value": v, "distance": distance}
            for p, v in points_values]
    return TransferPrior.from_rows(space, rows)


def test_transfer_prior_predict_and_noise_scale():
    space = golden_space()
    pts = space.sample(np.random.default_rng(2), 12)
    prior = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    pred = prior.predict(space.encode_many(pts))
    # NW at the observed points themselves must correlate strongly
    assert np.corrcoef(pred, prior.y)[0, 1] > 0.8
    assert prior.best_point() in [dict(p) for p in pts]
    # noise inflation: >= 1 everywhere, grows with real-observation count
    n0, n8 = prior.noise_scale(0, 24), prior.noise_scale(8, 24)
    assert (n0 >= 1.0).all() and (n8 > n0).all()
    # and with workload distance
    far = _prior_from(space, [(p, golden_objective(p)) for p in pts],
                      distance=0.9)
    assert (far.noise_scale(0, 24) > prior.noise_scale(0, 24)).all()


def test_warm_started_engine_first_ask_exploits_prior():
    """With a trustworthy neighbor prior, the first ask skips the LHS
    design phase and lands near the prior's optimum region."""
    space = golden_space()
    pts = space.sample(np.random.default_rng(3), 24)
    prior = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior)
    h = History(space)
    batch = eng.ask(1, h)
    assert len(batch) == 1
    # cold engine at the same seed is still in its LHS phase
    cold = BayesOpt(space, seed=0).ask(1, History(space))
    assert eng._init_points is None  # no LHS design was drawn
    best_prior_v = max(golden_objective(p) for p in pts)
    assert golden_objective(batch[0]) >= best_prior_v - 10.0
    assert cold != batch or True  # traces may coincide; the real pin is above


def test_prior_retires_after_decay_evals():
    space = golden_space()
    pts = space.sample(np.random.default_rng(4), 8)
    prior = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior, transfer_decay=4)
    h = History(space)
    for p in space.sample(np.random.default_rng(5), 4):
        v = golden_objective(p)
        eng.tell([Observation(point=p, value=v)])
        h.add(p, v)
    assert eng._active_prior(h) is None  # decayed out, permanently
    assert eng._prior_dropped


def test_negative_transfer_guard_drops_anticorrelated_prior():
    space = golden_space()
    pts = space.sample(np.random.default_rng(6), 16)
    # the prior claims the landscape is inverted
    prior = _prior_from(space, [(p, -golden_objective(p)) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior, transfer_guard_n=3)
    h = History(space)
    for p in space.sample(np.random.default_rng(7), 3):
        v = golden_objective(p)
        eng.tell([Observation(point=p, value=v)])
        h.add(p, v)
    assert eng._active_prior(h) is None
    assert eng._prior_dropped
    # an agreeing prior survives the same check
    good = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    eng2 = BayesOpt(space, seed=0, transfer_prior=good, transfer_guard_n=3)
    h2 = History(space)
    for p in space.sample(np.random.default_rng(7), 3):
        v = golden_objective(p)
        eng2.tell([Observation(point=p, value=v)])
        h2.add(p, v)
    assert eng2._active_prior(h2) is good
    assert not eng2._prior_dropped


# ---------------------------------------------------------------------------
# tuner integration: warm-start, pre-filter, unchanged-trace invariants
# ---------------------------------------------------------------------------

def test_tuner_records_into_corpus_and_warm_run_reuses_it(tmp_path):
    corpus_path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12, "bytes": 4e9}

    donor = FeaturedObjective(feats)
    t = Tuner(donor, space,
              TunerConfig(algorithm="random", budget=10, seed=0,
                          verbose=False,
                          transfer=TransferConfig(
                              corpus_path=str(corpus_path),
                              job_id="donor")))
    t.run()
    t.close()
    assert len(json.loads(corpus_path.read_text())) == 10

    # a BO job on a near workload builds a prior from the donor records
    warm_obj = FeaturedObjective({"flops": 1.1e12, "bytes": 4.4e9})
    warm = Tuner(warm_obj, space,
                 TunerConfig(algorithm="bo", budget=2, seed=0,
                             verbose=False,
                             transfer=TransferConfig(
                                 corpus_path=str(corpus_path),
                                 job_id="warm")))
    assert warm._transfer_prior is not None
    assert len(warm._transfer_prior) == 10
    assert warm.engine.transfer_prior is warm._transfer_prior
    assert warm._prefilter_on
    warm.close()

    # warm_start off -> the engine never sees the prior; the tuner-level
    # pre-filter is the only consumer and stays on
    filt = Tuner(FeaturedObjective({"flops": 1.1e12, "bytes": 4.4e9}), space,
                 TunerConfig(algorithm="bo", budget=2, seed=0,
                             verbose=False,
                             transfer=TransferConfig(
                                 corpus_path=str(corpus_path),
                                 job_id="filt", warm_start=False)))
    assert filt._transfer_prior is not None
    assert getattr(filt.engine, "transfer_prior", None) is None
    assert filt._prefilter_on
    filt.close()


def test_empty_or_dissimilar_corpus_leaves_trace_byte_identical(tmp_path):
    """A configured corpus with nothing relevant in it must not perturb
    the tuning trace at all — the golden parallelism=1 trace is
    reproduced byte-for-byte through the full transfer-enabled path."""
    empty = tmp_path / "empty.json"
    trace = GOLDEN["traces"]["bo:0"]
    t = Tuner(golden_objective, golden_space(),
              TunerConfig(algorithm="bo", budget=18, seed=0, verbose=False,
                          parallelism=1,
                          transfer=TransferConfig(corpus_path=str(empty),
                                                  job_id="fresh")))
    h = t.run()
    t.close()
    assert h.points() == trace["points"]
    assert [e.value for e in h.evals] == pytest.approx(trace["values"])


def test_prefilter_respects_unsafe_engines(tmp_path):
    """Nelder-Mead's speculative batches must never be pre-filtered."""
    corpus_path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(8), 12)
    _populate(corpus_path, "donor", feats,
              [(p, golden_objective(p)) for p in pts])
    t = Tuner(FeaturedObjective(feats), space,
              TunerConfig(algorithm="nms", budget=4, seed=0, verbose=False,
                          transfer=TransferConfig(
                              corpus_path=str(corpus_path), job_id="nms")))
    assert t._transfer_prior is not None  # the prior exists...
    assert not t._prefilter_on            # ...but NMS opts out
    t.run()
    t.close()


def test_prefilter_never_promotes_random_fills_over_ranked_head(tmp_path):
    """An engine that pads an exhausted candidate pool with random fills
    reports the ranked head (``last_ask_ranked``); the filter must only
    re-rank the head — a fill scored by the same prior must never
    displace a candidate the engine actually ranked."""
    corpus_path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(9), 8)
    _populate(corpus_path, "donor", feats,
              [(p, golden_objective(p)) for p in pts])
    t = Tuner(FeaturedObjective(feats), space,
              TunerConfig(algorithm="random", budget=4, seed=0, verbose=False,
                          transfer=TransferConfig(
                              corpus_path=str(corpus_path), job_id="me",
                              keep_fraction=0.4)))
    assert t._prefilter_on
    cands = space.sample(np.random.default_rng(10), 5)

    class FakePrior:  # scores strictly increasing by candidate index
        def predict(self, X):
            return np.arange(np.asarray(X).shape[0], dtype=float)

    t._transfer_prior = FakePrior()
    t.engine.ask = lambda n, h: [dict(c) for c in cands[:n]]
    # no ranked/fill boundary declared: the whole batch competes
    t.engine.last_ask_ranked = None
    assert t._ask_filtered(2, t.history) == [cands[3], cands[4]]
    # ranked head longer than want: filter picks within the head only;
    # the fill tail (cands[4], the prior's favorite) is excluded
    t.engine.last_ask_ranked = 4
    assert t._ask_filtered(2, t.history) == [cands[2], cands[3]]
    # ranked head shorter than want: the whole head survives unfiltered
    # and fills only top up the deficit, in engine order
    t.engine.last_ask_ranked = 1
    assert t._ask_filtered(2, t.history) == [cands[0], cands[1]]
    t.close()


def test_warm_bo_reports_ranked_head_when_padding():
    """BayesOpt's transfer ask marks where acquisition-ranked candidates
    end and random fills begin."""
    space = SearchSpace.from_dicts([
        {"type": "int", "name": "inter_op", "min": 1, "max": 6}])
    pts = [dict(p) for p in space.enumerate()]
    prior = _prior_from(space, [(p, float(p["inter_op"])) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior)
    h = History(space)
    for p in pts[:3]:
        v = float(p["inter_op"])
        eng.tell([Observation(point=p, value=v)])
        h.add(p, v)
    batch = eng.ask(5, h)
    assert len(batch) == 5
    # 3 unseen grid points were acquisition-ranked; 2 were random fills
    assert eng.last_ask_ranked == 3


def test_exhaustive_sweep_is_never_prefiltered(tmp_path):
    """Exhaustive's asks consume a one-shot grid iterator: a pre-filtered
    point would never be re-proposed, so an 'exhaustive' sweep with a
    corpus attached (the service attaches one to every job) would
    silently skip grid points — it must opt out and still cover the
    whole grid."""
    from repro.core.exhaustive import Exhaustive

    assert Exhaustive.prefilter_safe is False

    corpus_path = tmp_path / "corpus.json"
    space = SearchSpace.from_dicts([
        {"type": "int", "name": "inter_op", "min": 1, "max": 4},
        {"type": "cat", "name": "build", "choices": [0, 1, 2]},
    ])
    feats = {"flops": 1e12}
    pts = [dict(p) for p in space.enumerate()]
    _populate(corpus_path, "donor", feats,
              [(p, float(i)) for i, p in enumerate(pts)], space=space)

    obj = FeaturedObjective(feats, value_fn=lambda p: float(p["inter_op"]))
    t = Tuner(obj, space,
              TunerConfig(algorithm="exhaustive", budget=len(pts) + 5,
                          seed=0, verbose=False,
                          transfer=TransferConfig(
                              corpus_path=str(corpus_path), job_id="sweep")))
    assert t._transfer_prior is not None  # the prior exists...
    assert not t._prefilter_on            # ...but exhaustive opts out
    h = t.run()
    t.close()
    # every grid point was measured exactly once — nothing skipped
    assert sorted(space.key(p) for p in h.points()) \
        == sorted(space.key(p) for p in pts)


def test_transfer_config_roundtrip_and_unknown_key_rejection():
    cfg = TunerConfig(algorithm="bo", budget=5,
                      transfer=TransferConfig(corpus_path="c.json",
                                              keep_fraction=0.25))
    d = cfg.to_dict()
    assert d["transfer"]["corpus_path"] == "c.json"
    back = TunerConfig.from_dict(d)
    assert back.transfer.to_dict() == cfg.transfer.to_dict()
    assert bool(back.transfer)
    assert not bool(TunerConfig(algorithm="bo", budget=5).transfer)
    with pytest.raises(ValueError, match="keep_fractoin"):
        TunerConfig.from_dict(
            {"algorithm": "bo", "budget": 5,
             "transfer": {"corpus_path": "c.json", "keep_fractoin": 0.5}})


def test_legacy_tell_signature_still_warns():
    """The deprecation shim stays behaviorally exact while every repro-
    internal caller is held to the Observation API by the pytest
    ``filterwarnings = error::DeprecationWarning:repro`` gate."""
    from repro.core import ENGINES

    space = golden_space()
    eng = ENGINES["random"](space, seed=0)
    p = {"inter_op": 1, "intra_op": 0, "build": 1}
    with pytest.warns(DeprecationWarning, match="pass a sequence of"):
        eng.tell([p], [1.5], [0.25])
    assert eng.mean_cost_seconds == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# executor hooks
# ---------------------------------------------------------------------------

def test_executor_records_real_measurements_only(tmp_path):
    space = golden_space()
    corpus = TuningCorpus(tmp_path / "corpus.json", job_id="exec")
    obj = FeaturedObjective({"flops": 1e12})
    ex = EvaluationExecutor(obj, space, parallelism=1, corpus=corpus)
    assert corpus.descriptor is not None  # executor bound the descriptor
    p1 = {"inter_op": 1, "intra_op": 0, "build": 1}
    p2 = {"inter_op": 2, "intra_op": 5, "build": 2}
    ex.evaluate([p1, p2])
    ex.evaluate([p1])  # memo hit: must NOT be re-recorded
    ex.close()
    recs = TuningCorpus(tmp_path / "corpus.json", job_id="other").records()
    assert len(recs) == 2
    assert {tuple(space.key(r["point"])) for r in recs} \
        == {space.key(p1), space.key(p2)}
    assert all(r["workload"]["job_id"] == "exec" for r in recs)


# ---------------------------------------------------------------------------
# strict grid-key serialization (the default=str regression)
# ---------------------------------------------------------------------------

def test_store_key_coerces_numpy_scalars_losslessly():
    """Numpy scalars (a space built from np.linspace / np.arange values)
    canonicalize via .item(): the store key is byte-identical to the
    plain-Python spelling, so memoization keeps working for store and
    lookup alike instead of hard-failing at persist time."""
    assert _store_key((np.int64(3), "x")) == _store_key((3, "x"))
    assert _store_key((np.float64(0.5), np.bool_(True))) \
        == _store_key((0.5, True))
    # and inside the (tuple-shaped) fidelity marker
    assert _store_key(memo_key(("a", np.int64(2)), np.float64(0.25))) \
        == _store_key(memo_key(("a", 2), 0.25))


def test_store_key_rejects_non_json_components():
    """TypeError is reserved for genuinely non-JSON objects."""
    with pytest.raises(TypeError, match="not strictly JSON-serializable"):
        _store_key((object(), 1))


def test_store_key_roundtrips_fidelity_marker():
    key = memo_key(("a", 2, 1), 0.25)
    skey = _store_key(key)
    assert json.loads(skey)[-1] == ["__fidelity__", 0.25]
    assert MemoCache._stored_fidelity(skey) == 0.25
    full = _store_key(memo_key(("a", 2, 1), None))
    assert MemoCache._stored_fidelity(full) is None


def test_memo_cache_numpy_key_memoizes_to_same_slot(tmp_path):
    from repro.tuning.cache import JsonCacheStore

    path = tmp_path / "memo.json"
    cache = MemoCache(store=JsonCacheStore(path))
    cache.put((np.int64(3), "x"), EvalResult({"a": 1}, 2.0, 0.1, {}))
    cache.put((3, "x"), EvalResult({"a": 1}, 2.0, 0.1, {}))
    # numpy and plain spellings hash/compare equal in memory and collapse
    # to ONE canonical store key on disk — not a colliding pair
    assert cache.get((3, "x")).value == 2.0
    on_disk = json.loads(path.read_text())
    assert list(on_disk) == [_store_key((3, "x"))]
