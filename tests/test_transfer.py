"""Transfer learning across tuning jobs: corpus storage + similarity,
surrogate warm-starts, the negative-transfer guard, the candidate
pre-filter, and the strict-serialization fix for persisted grid keys.

The no-corpus golden traces are pinned in test_executor.py /
test_async_loop.py; here the complementary invariant is pinned: a
*configured but unhelpful* corpus (empty, or beyond ``max_distance``)
must leave the tuning trace byte-identical too.
"""
import json
import math
import pathlib

import numpy as np
import pytest

from repro.core import (History, Observation, SearchSpace, TransferConfig,
                        Tuner, TunerConfig)
from repro.core.bayesopt import BayesOpt, TransferPrior
from repro.tuning.corpus import (TuningCorpus, prediction_agreement,
                                 space_fingerprint, task_features,
                                 workload_distance)
from repro.tuning.executor import (EvalResult, EvaluationExecutor, MemoCache,
                                   _store_key, memo_key)
from repro.tuning.objective import Evaluator

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "ask_tell_traces.json")
    .read_text())


def golden_space() -> SearchSpace:
    return SearchSpace.from_dicts(GOLDEN["space"])


def golden_objective(p):
    a, b, c = p["inter_op"], p["intra_op"], p["build"]
    return float(50.0 * pow(2.718281828, -((a - 11) / 5.0) ** 2)
                 + 0.3 * b - 0.004 * (b - 25) ** 2 + 7.0 * c)


class FeaturedObjective(Evaluator):
    """Synthetic workload with declared task features."""

    def __init__(self, features, value_fn=golden_objective):
        self.features = dict(features)
        self.value_fn = value_fn
        self.calls = 0

    def task_features(self):
        return dict(self.features)

    def __call__(self, p, fidelity=None):
        self.calls += 1
        return self.value_fn(p), {"cost_seconds": 0.01}


def _populate(corpus_path, job_id, features, points_values,
              space=None, objective=None):
    space = space or golden_space()
    corpus = TuningCorpus(corpus_path, job_id=job_id)
    corpus.describe_job(objective or FeaturedObjective(features), space)
    for p, v in points_values:
        corpus.add(p, v, cost_seconds=0.02)
    corpus.flush()
    return corpus


# ---------------------------------------------------------------------------
# similarity layer
# ---------------------------------------------------------------------------

def test_workload_distance_properties():
    a = {"flops": 1e12, "bytes": 4e9}
    assert workload_distance(a, a) == 0.0
    assert workload_distance({}, {}) == 0.0  # same space, nothing known
    near = {"flops": 1.1e12, "bytes": 4.4e9}
    far = {"flops": 1e14, "bytes": 4e11}
    assert workload_distance(a, near) < workload_distance(a, far)
    # a feature only one side declares counts as maximally different
    assert workload_distance(a, {"flops": 1e12}) == pytest.approx(0.5)
    # symmetric
    assert workload_distance(a, far) == pytest.approx(
        workload_distance(far, a))


def test_task_features_coercion_and_fallbacks():
    assert task_features(lambda p: 1.0) == {}  # plain callables: no hook
    obj = FeaturedObjective({"flops": 5, "bad": "nan-ish",
                             "inf": float("inf")})
    obj.features["bad"] = float("nan")
    feats = task_features(obj)
    assert feats == {"flops": 5.0}  # non-finite / non-numeric dropped

    class Exploding:
        def task_features(self):
            raise RuntimeError("harness not built yet")

    assert task_features(Exploding()) == {}


def test_prediction_agreement_degenerate_cases():
    assert prediction_agreement([1.0], [2.0]) is None  # < 2 pairs
    assert prediction_agreement([1, 2], [5, 5]) is None  # constant side
    assert prediction_agreement([1, 2, 3], [1, 2]) is None  # mismatch
    assert prediction_agreement([1, 2, 3], [2, 4, 6]) == pytest.approx(1.0)
    assert prediction_agreement([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)


# ---------------------------------------------------------------------------
# corpus storage + neighbor selection
# ---------------------------------------------------------------------------

def test_corpus_roundtrip_persists_across_instances(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(0), 4)
    _populate(path, "writer", feats, [(p, golden_objective(p)) for p in pts])

    reader = TuningCorpus(path, job_id="reader")
    recs = reader.records()
    assert len(recs) == 4
    for rec in recs:
        assert rec["workload"]["job_id"] == "writer"
        assert rec["workload"]["space"] == space_fingerprint(space)
        assert rec["cost_seconds"] == pytest.approx(0.02)
    rows = reader.prior_observations(space, feats)
    assert len(rows) == 4
    assert all(r["distance"] == 0.0 for r in rows)


def test_corpus_add_requires_descriptor(tmp_path):
    corpus = TuningCorpus(tmp_path / "c.json", job_id="j")
    with pytest.raises(RuntimeError, match="describe_job"):
        corpus.add({"inter_op": 1, "intra_op": 0, "build": 1}, 1.0)


def test_neighbors_filter_space_distance_and_own_job(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    p = {"inter_op": 3, "intra_op": 10, "build": 1}
    base = {"flops": 1e12, "bytes": 4e9}
    _populate(path, "near", base, [(p, 1.0)])
    _populate(path, "far", {"flops": 1e15, "bytes": 4e12}, [(p, 2.0)])
    other_space = SearchSpace.from_dicts(
        [{"type": "int", "name": "inter_op", "min": 1, "max": 4}])
    _populate(path, "other-space", base,
              [({"inter_op": 2}, 3.0)], space=other_space)

    reader = TuningCorpus(path, job_id="me")
    near = reader.neighbors(space, base)
    assert [g["job_id"] for g in near] == ["near"]  # far + other-space cut
    assert near[0]["distance"] == 0.0
    # a job never sees itself as a neighbor (no self-transfer)
    assert reader.neighbors(space, base, exclude_job="near") == []


def test_prior_observations_skip_failures_and_stale_points(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    good = {"inter_op": 3, "intra_op": 10, "build": 1}
    stale = {"inter_op": 99, "intra_op": 10, "build": 1}  # not on the grid
    _populate(path, "donor", feats,
              [(good, 5.0), (good, float("-inf")), (stale, 9.0)])
    rows = TuningCorpus(path, job_id="me").prior_observations(space, feats)
    assert [r["value"] for r in rows] == [5.0]


def test_prior_observations_quota_keeps_value_spread(tmp_path):
    path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(1), 30)
    _populate(path, "donor", feats,
              [(p, float(i)) for i, p in enumerate(pts)])
    rows = TuningCorpus(path, job_id="me").prior_observations(
        space, feats, max_rows=8)
    values = sorted(r["value"] for r in rows)
    assert len(rows) <= 8
    assert values[0] == 0.0 and values[-1] == 29.0  # floor and peak kept


# ---------------------------------------------------------------------------
# TransferPrior + engine warm-start
# ---------------------------------------------------------------------------

def _prior_from(space, points_values, distance=0.1):
    rows = [{"point": p, "value": v, "distance": distance}
            for p, v in points_values]
    return TransferPrior.from_rows(space, rows)


def test_transfer_prior_predict_and_noise_scale():
    space = golden_space()
    pts = space.sample(np.random.default_rng(2), 12)
    prior = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    pred = prior.predict(space.encode_many(pts))
    # NW at the observed points themselves must correlate strongly
    assert np.corrcoef(pred, prior.y)[0, 1] > 0.8
    assert prior.best_point() in [dict(p) for p in pts]
    # noise inflation: >= 1 everywhere, grows with real-observation count
    n0, n8 = prior.noise_scale(0, 24), prior.noise_scale(8, 24)
    assert (n0 >= 1.0).all() and (n8 > n0).all()
    # and with workload distance
    far = _prior_from(space, [(p, golden_objective(p)) for p in pts],
                      distance=0.9)
    assert (far.noise_scale(0, 24) > prior.noise_scale(0, 24)).all()


def test_warm_started_engine_first_ask_exploits_prior():
    """With a trustworthy neighbor prior, the first ask skips the LHS
    design phase and lands near the prior's optimum region."""
    space = golden_space()
    pts = space.sample(np.random.default_rng(3), 24)
    prior = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior)
    h = History(space)
    batch = eng.ask(1, h)
    assert len(batch) == 1
    # cold engine at the same seed is still in its LHS phase
    cold = BayesOpt(space, seed=0).ask(1, History(space))
    assert eng._init_points is None  # no LHS design was drawn
    best_prior_v = max(golden_objective(p) for p in pts)
    assert golden_objective(batch[0]) >= best_prior_v - 10.0
    assert cold != batch or True  # traces may coincide; the real pin is above


def test_prior_retires_after_decay_evals():
    space = golden_space()
    pts = space.sample(np.random.default_rng(4), 8)
    prior = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior, transfer_decay=4)
    h = History(space)
    for p in space.sample(np.random.default_rng(5), 4):
        v = golden_objective(p)
        eng.tell([Observation(point=p, value=v)])
        h.add(p, v)
    assert eng._active_prior(h) is None  # decayed out, permanently
    assert eng._prior_dropped


def test_negative_transfer_guard_drops_anticorrelated_prior():
    space = golden_space()
    pts = space.sample(np.random.default_rng(6), 16)
    # the prior claims the landscape is inverted
    prior = _prior_from(space, [(p, -golden_objective(p)) for p in pts])
    eng = BayesOpt(space, seed=0, transfer_prior=prior, transfer_guard_n=3)
    h = History(space)
    for p in space.sample(np.random.default_rng(7), 3):
        v = golden_objective(p)
        eng.tell([Observation(point=p, value=v)])
        h.add(p, v)
    assert eng._active_prior(h) is None
    assert eng._prior_dropped
    # an agreeing prior survives the same check
    good = _prior_from(space, [(p, golden_objective(p)) for p in pts])
    eng2 = BayesOpt(space, seed=0, transfer_prior=good, transfer_guard_n=3)
    h2 = History(space)
    for p in space.sample(np.random.default_rng(7), 3):
        v = golden_objective(p)
        eng2.tell([Observation(point=p, value=v)])
        h2.add(p, v)
    assert eng2._active_prior(h2) is good
    assert not eng2._prior_dropped


# ---------------------------------------------------------------------------
# tuner integration: warm-start, pre-filter, unchanged-trace invariants
# ---------------------------------------------------------------------------

def test_tuner_records_into_corpus_and_warm_run_reuses_it(tmp_path):
    corpus_path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12, "bytes": 4e9}

    donor = FeaturedObjective(feats)
    t = Tuner(donor, space,
              TunerConfig(algorithm="random", budget=10, seed=0,
                          verbose=False,
                          transfer=TransferConfig(
                              corpus_path=str(corpus_path),
                              job_id="donor")))
    t.run()
    t.close()
    assert len(json.loads(corpus_path.read_text())) == 10

    # a BO job on a near workload builds a prior from the donor records
    warm_obj = FeaturedObjective({"flops": 1.1e12, "bytes": 4.4e9})
    warm = Tuner(warm_obj, space,
                 TunerConfig(algorithm="bo", budget=2, seed=0,
                             verbose=False,
                             transfer=TransferConfig(
                                 corpus_path=str(corpus_path),
                                 job_id="warm")))
    assert warm._transfer_prior is not None
    assert len(warm._transfer_prior) == 10
    assert warm.engine.transfer_prior is warm._transfer_prior
    assert warm._prefilter_on
    warm.close()


def test_empty_or_dissimilar_corpus_leaves_trace_byte_identical(tmp_path):
    """A configured corpus with nothing relevant in it must not perturb
    the tuning trace at all — the golden parallelism=1 trace is
    reproduced byte-for-byte through the full transfer-enabled path."""
    empty = tmp_path / "empty.json"
    trace = GOLDEN["traces"]["bo:0"]
    t = Tuner(golden_objective, golden_space(),
              TunerConfig(algorithm="bo", budget=18, seed=0, verbose=False,
                          parallelism=1,
                          transfer=TransferConfig(corpus_path=str(empty),
                                                  job_id="fresh")))
    h = t.run()
    t.close()
    assert h.points() == trace["points"]
    assert [e.value for e in h.evals] == pytest.approx(trace["values"])


def test_prefilter_respects_unsafe_engines(tmp_path):
    """Nelder-Mead's speculative batches must never be pre-filtered."""
    corpus_path = tmp_path / "corpus.json"
    space = golden_space()
    feats = {"flops": 1e12}
    pts = space.sample(np.random.default_rng(8), 12)
    _populate(corpus_path, "donor", feats,
              [(p, golden_objective(p)) for p in pts])
    t = Tuner(FeaturedObjective(feats), space,
              TunerConfig(algorithm="nms", budget=4, seed=0, verbose=False,
                          transfer=TransferConfig(
                              corpus_path=str(corpus_path), job_id="nms")))
    assert t._transfer_prior is not None  # the prior exists...
    assert not t._prefilter_on            # ...but NMS opts out
    t.run()
    t.close()


def test_transfer_config_roundtrip_and_unknown_key_rejection():
    cfg = TunerConfig(algorithm="bo", budget=5,
                      transfer=TransferConfig(corpus_path="c.json",
                                              keep_fraction=0.25))
    d = cfg.to_dict()
    assert d["transfer"]["corpus_path"] == "c.json"
    back = TunerConfig.from_dict(d)
    assert back.transfer.to_dict() == cfg.transfer.to_dict()
    assert bool(back.transfer)
    assert not bool(TunerConfig(algorithm="bo", budget=5).transfer)
    with pytest.raises(ValueError, match="keep_fractoin"):
        TunerConfig.from_dict(
            {"algorithm": "bo", "budget": 5,
             "transfer": {"corpus_path": "c.json", "keep_fractoin": 0.5}})


def test_legacy_tell_signature_still_warns():
    """The deprecation shim stays behaviorally exact while every repro-
    internal caller is held to the Observation API by the pytest
    ``filterwarnings = error::DeprecationWarning:repro`` gate."""
    from repro.core import ENGINES

    space = golden_space()
    eng = ENGINES["random"](space, seed=0)
    p = {"inter_op": 1, "intra_op": 0, "build": 1}
    with pytest.warns(DeprecationWarning, match="pass a sequence of"):
        eng.tell([p], [1.5], [0.25])
    assert eng.mean_cost_seconds == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# executor hooks
# ---------------------------------------------------------------------------

def test_executor_records_real_measurements_only(tmp_path):
    space = golden_space()
    corpus = TuningCorpus(tmp_path / "corpus.json", job_id="exec")
    obj = FeaturedObjective({"flops": 1e12})
    ex = EvaluationExecutor(obj, space, parallelism=1, corpus=corpus)
    assert corpus.descriptor is not None  # executor bound the descriptor
    p1 = {"inter_op": 1, "intra_op": 0, "build": 1}
    p2 = {"inter_op": 2, "intra_op": 5, "build": 2}
    ex.evaluate([p1, p2])
    ex.evaluate([p1])  # memo hit: must NOT be re-recorded
    ex.close()
    recs = TuningCorpus(tmp_path / "corpus.json", job_id="other").records()
    assert len(recs) == 2
    assert {tuple(space.key(r["point"])) for r in recs} \
        == {space.key(p1), space.key(p2)}
    assert all(r["workload"]["job_id"] == "exec" for r in recs)


# ---------------------------------------------------------------------------
# strict grid-key serialization (the default=str regression)
# ---------------------------------------------------------------------------

def test_store_key_rejects_non_json_components():
    with pytest.raises(TypeError, match="np.int64|int64"):
        _store_key((np.int64(3), "x"))
    with pytest.raises(TypeError, match="not strictly JSON-serializable"):
        _store_key((object(), 1))


def test_store_key_roundtrips_fidelity_marker():
    key = memo_key(("a", 2, 1), 0.25)
    skey = _store_key(key)
    assert json.loads(skey)[-1] == ["__fidelity__", 0.25]
    assert MemoCache._stored_fidelity(skey) == 0.25
    full = _store_key(memo_key(("a", 2, 1), None))
    assert MemoCache._stored_fidelity(full) is None


def test_memo_cache_put_with_numpy_key_fails_loudly(tmp_path):
    from repro.tuning.cache import JsonCacheStore

    cache = MemoCache(store=JsonCacheStore(tmp_path / "memo.json"))
    ok_key = (3, "x")
    cache.put(ok_key, EvalResult({"a": 1}, 2.0, 0.1, {}))
    assert cache.get(ok_key).value == 2.0
    with pytest.raises(TypeError, match="grid key"):
        cache.put((np.int64(3), "x"), EvalResult({"a": 1}, 2.0, 0.1, {}))
