"""Deterministic synthetic token pipeline.

Stateless: ``batch_at(step)`` is a pure function of (seed, step), so any
worker can reproduce any batch — this is what makes checkpoint/restart and
elastic rescaling exact (a restored run consumes the identical stream).
Tokens follow a noisy affine bigram process so models have a learnable
signal (train-loss-decreases tests rely on it).

A background prefetch thread overlaps host batch synthesis with device
compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


def _mix(a: np.ndarray) -> np.ndarray:
    """splitmix64-style integer hash (vectorized, deterministic)."""
    a = (a + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    a ^= a >> np.uint64(30)
    a = (a * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
    a ^= a >> np.uint64(27)
    a = (a * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
    return a ^ (a >> np.uint64(31))


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1  # fraction of purely random tokens


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, *, shard: int = 0, num_shards: int = 1) -> Dict:
        """Batch for ``step``; optionally only this host's shard of it."""
        c = self.cfg
        assert c.global_batch % num_shards == 0
        b = c.global_batch // num_shards
        rows = (np.arange(b) + shard * b).astype(np.uint64)
        base = _mix(
            rows[:, None] * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(7_919)
            + np.uint64(c.seed) * np.uint64(104_729)
        )
        # noisy affine bigram stream: x_{t+1} = 3 x_t + 7 (mod V), with
        # `noise`-fraction random substitutions
        V = c.vocab_size
        toks = np.empty((b, c.seq_len + 1), np.int64)
        toks[:, 0] = base[:, 0] % V
        h = base[:, 0]
        for t in range(1, c.seq_len + 1):
            h = _mix(h + np.uint64(t))
            rand_tok = (h % np.uint64(V)).astype(np.int64)
            is_noise = (h >> np.uint64(40)).astype(np.float64) / float(2 ** 24) < c.noise
            nxt = (toks[:, t - 1] * 3 + 7) % V
            toks[:, t] = np.where(is_noise, rand_tok, nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of upcoming batches (depth-bounded)."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
