from repro.configs.base import (
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SHAPES,
    ShapeConfig,
    applicable,
)
from repro.configs.registry import all_cells, get_config, get_shape, list_archs

__all__ = [
    "MLAConfig",
    "MambaConfig",
    "ModelConfig",
    "MoEConfig",
    "RWKVConfig",
    "SHAPES",
    "ShapeConfig",
    "applicable",
    "all_cells",
    "get_config",
    "get_shape",
    "list_archs",
]
