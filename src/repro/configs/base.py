"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s.  Configs are plain frozen
dataclasses so they hash, compare, and print cleanly, and so the tuner can
treat "a point in backend-parameter space applied to a config" as a pure
value.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs for architecture families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN settings (GShard-style top-k routing)."""

    num_experts: int
    top_k: int
    d_expert: int  # hidden width of each expert FFN
    every: int = 1  # MoE FFN on layers where (layer_idx % every == every-1)
    capacity_factor: float = 1.25
    num_shared_experts: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective-SSM block (Jamba's SSM layer)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, math.ceil(d_model / 16))


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 'Finch' block (data-dependent decay linear recurrence)."""

    head_size: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    gate_lora: int = 0  # 0 => d_model // 2 is typical; we use full proj


# ---------------------------------------------------------------------------
# The main model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "hybrid", "ssm", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 => d_model // num_heads
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA window (h2o-danube)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "silu"  # mlp activation ("silu" -> SwiGLU, "gelu" -> GeGLU-less)
    tie_embeddings: bool = False

    # family extras
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # hybrid interleave: layer i is attention iff i % attn_period == attn_offset,
    # otherwise the SSM mixer. attn_period=0 => all-attention.
    attn_period: int = 0
    attn_offset: int = 0

    # encoder-decoder (whisper): number of encoder layers (decoder = num_layers)
    encoder_layers: int = 0
    encoder_seq_len: int = 0  # stub frontend sequence length (audio frames)

    # vlm stub frontend: number of image tokens whose embeddings arrive
    # precomputed from the (stubbed) vision tower.
    num_frontend_tokens: int = 0

    # embedding/head tables are padded up to a multiple of this so the vocab
    # dim shards over the model axis (e.g. whisper's 51865 -> 52224); padded
    # classes are never targets and standard CE handles them.
    vocab_pad_multiple: int = 256

    # --- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.rwkv is not None and self.attn_period == 0

    @property
    def has_subquadratic_path(self) -> bool:
        """True if the arch can serve 500k-token contexts (bounded state/KV)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def mixer_kind(self, layer_idx: int) -> str:
        """Which sequence mixer layer ``layer_idx`` uses."""
        if self.rwkv is not None:
            return "rwkv"
        if self.mamba is not None:
            if self.attn_period and layer_idx % self.attn_period == self.attn_offset:
                return "mla" if self.mla else "attn"
            return "mamba"
        if self.mla is not None:
            return "mla"
        return "attn"

    def mlp_kind(self, layer_idx: int) -> str:
        if self.moe is not None and layer_idx % self.moe.every == self.moe.every - 1:
            return "moe"
        return "dense"

    def layer_plan(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(
            (self.mixer_kind(i), self.mlp_kind(i)) for i in range(self.num_layers)
        )

    def layer_period(self) -> int:
        """Smallest repeating period of the layer plan (for scan-over-periods)."""
        plan = self.layer_plan()
        n = len(plan)
        for p in range(1, n + 1):
            if n % p == 0 and plan == plan[:p] * (n // p):
                return p
        return n

    # --- parameter count (for MODEL_FLOPS = 6 N D) --------------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and per-token-active."""
        d, hd = self.d_model, self.resolved_head_dim
        nh, nkv = self.num_heads, self.num_kv_heads
        total = 0
        active = 0
        embed = self.padded_vocab * d
        total += embed + (0 if self.tie_embeddings else embed)
        active += embed + (0 if self.tie_embeddings else embed)

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * nh * qk_head
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
                p += nh * m.v_head_dim * d
                return p
            p = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            if self.qkv_bias:
                p += (nh + 2 * nkv) * hd
            return p

        def mamba_params() -> int:
            mc = self.mamba
            d_in = mc.expand * d
            dtr = mc.resolved_dt_rank(d)
            p = d * 2 * d_in  # in_proj
            p += d_in * mc.d_conv  # depthwise conv
            p += d_in * (dtr + 2 * mc.d_state)  # x_proj
            p += dtr * d_in + d_in  # dt_proj
            p += d_in * mc.d_state + d_in  # A_log, D
            p += d_in * d  # out_proj
            return p

        def rwkv_params() -> int:
            rc = self.rwkv
            p = 4 * d * d  # r, k, v, output projections
            p += d * d  # gate
            p += 2 * (d * rc.decay_lora + rc.decay_lora * d)  # w lora + dt lora
            p += 5 * (d + 2 * d * rc.mix_lora)  # token-shift ddlerp loras
            p += 2 * d  # ln_x params
            return p

        def dense_mlp() -> int:
            return 3 * d * self.d_ff  # SwiGLU: gate, up, down

        def moe_mlp() -> int:
            m = self.moe
            router = d * m.num_experts
            expert = 3 * d * m.d_expert
            return router + m.num_experts * expert, router + m.top_k * expert

        for i in range(self.num_layers):
            mk, fk = self.mixer_kind(i), self.mlp_kind(i)
            mp = {"attn": attn_params, "mla": attn_params, "mamba": mamba_params,
                  "rwkv": rwkv_params}[mk]()
            total += mp + 2 * d
            active += mp + 2 * d
            if fk == "moe":
                t, a = moe_mlp()
                total += t
                active += a
            else:
                total += dense_mlp()
                active += dense_mlp()
        # encoder stack (whisper): attention + cross-attn sized like decoder
        if self.encoder_layers:
            enc_layer = attn_params() + dense_mlp() + 2 * d
            cross = self.num_layers * (attn_params() + d)
            total += self.encoder_layers * enc_layer + cross
            active += self.encoder_layers * enc_layer + cross
        total += d  # final norm
        active += d
        return {"total": int(total), "active": int(active)}

    # --- reduced config for CPU smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config: same layer plan structure, small dims."""
        period = self.layer_period()
        n_layers = max(period, min(self.num_layers, 2 * period))
        kw = dict(
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            vocab_pad_multiple=1,
            head_dim=16,
            encoder_seq_len=16 if self.encoder_layers else 0,
            encoder_layers=min(self.encoder_layers, 2),
            num_frontend_tokens=min(self.num_frontend_tokens, 4),
            sliding_window=8 if self.sliding_window else None,
        )
        if self.moe:
            kw["moe"] = MoEConfig(
                num_experts=4, top_k=min(self.moe.top_k, 2), d_expert=32,
                every=self.moe.every, capacity_factor=self.moe.capacity_factor,
            )
        if self.mla:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.mamba:
            kw["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=8)
        if self.rwkv:
            kw["rwkv"] = RWKVConfig(head_size=16, decay_lora=8, mix_lora=8)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs; reason string if skipped."""
    if shape.name == "long_500k" and not cfg.has_subquadratic_path:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
