"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, applicable

_ARCH_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "whisper-base": "repro.configs.whisper_base",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> List[dict]:
    """The full 40-cell (arch x shape) matrix with applicability flags."""
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = applicable(cfg, shape)
            cells.append(
                {"arch": arch, "shape": sname, "runnable": ok, "skip_reason": reason}
            )
    return cells


__all__ = ["list_archs", "get_config", "get_shape", "all_cells", "SHAPES"]
