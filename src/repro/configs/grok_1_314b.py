"""Grok-1 314B — MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, every layer MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32768, every=1),
)
