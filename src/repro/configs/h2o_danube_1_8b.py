"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818]  24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
Sliding window 4096 (mistral-style), which bounds the decode KV cache and
makes the arch sub-quadratic => the long_500k cell runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    head_dim=80,
    sliding_window=4096,
)
