"""InternVL2-26B — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

[arXiv:2404.16821]  Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The vision tower is stubbed per the assignment:
``input_specs()`` provides precomputed patch embeddings (256 image tokens
after pixel-shuffle) which replace the first ``num_frontend_tokens`` token
embeddings of the sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    num_frontend_tokens=256,
)
