"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Jamba block: period of 8 layers with a single attention layer (index 4 of
the period in the reference implementation), MoE replacing the dense FFN on
every second layer (e=16, top-2).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    attn_period=8,
    attn_offset=4,
)
