"""MiniCPM3-4B — dense with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B]  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA ranks follow the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=96,  # qk head dim = nope(64) + rope(32)
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
)
