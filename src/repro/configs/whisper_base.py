"""Whisper-base — encoder-decoder, conv frontend (STUB).  [arXiv:2212.04356]

6L (enc) + 6L (dec), d_model=512 8H d_ff=2048 vocab=51865.  The conv1d mel
frontend is stubbed: ``input_specs()`` provides 1500 precomputed frame
embeddings for the encoder.  Attention is full MHA (kv=8 == heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    encoder_layers=6,
    encoder_seq_len=1500,
)
