"""Optimizers (pure-JAX pytrees): AdamW with large-scale memory options.

* ``state_dtype="f32"``   — standard AdamW (fp32 m, v).
* ``state_dtype="bf16"``  — m, v stored bf16 (halves optimizer HBM; the
  update math still runs fp32).  Used for the 314B-param grok cell.
* ``factored=True``       — Adafactor-style factored second moment for
  rank>=2 params (row/col means instead of full v): O(n+m) not O(nm).

Optimizer state inherits parameter sharding (ZeRO-1 for free under pjit:
m/v shard exactly like their parameter).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "f32"  # f32 | bf16
    factored: bool = False


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * decay


def _state_dt(cfg: OptimizerConfig):
    return jnp.bfloat16 if cfg.state_dtype == "bf16" else jnp.float32


def _is_factorable(p: jax.Array) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def adamw_init(params, cfg: OptimizerConfig) -> dict:
    sdt = _state_dt(cfg)

    def make_m(p):
        return jnp.zeros_like(p, dtype=sdt)

    def make_v(p):
        if cfg.factored and _is_factorable(p):
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return jnp.zeros_like(p, dtype=sdt)

    return {
        "m": jax.tree_util.tree_map(make_m, params),
        "v": jax.tree_util.tree_map(make_v, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, state: dict, params, cfg: OptimizerConfig
) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    sdt = _state_dt(cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        if isinstance(v, dict):  # factored second moment
            g2 = g * g + 1e-30
            row = b2 * v["row"] + (1 - b2) * g2.mean(axis=-1)
            col = b2 * v["col"] + (1 - b2) * g2.mean(axis=-2)
            v_new = {"row": row, "col": col}
            # reconstruct: v ~ row x col / mean(row)
            denom = jnp.maximum(row.mean(axis=-1, keepdims=True), 1e-30)
            v32 = (row[..., None] * col[..., None, :] / denom[..., None]) / bc2
        else:
            v_new = (b2 * v.astype(jnp.float32) + (1 - b2) * g * g)
            v32 = v_new / bc2
            v_new = v_new.astype(sdt)
        mhat = m32 / bc1
        step = mhat / (jnp.sqrt(v32) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m32.astype(sdt), v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm, "clip": clip}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics


def optimizer_state_axes(params_axes, cfg: OptimizerConfig, params_values):
    """Logical axes tree for the optimizer state (mirrors the params)."""

    def v_axes(axes, p):
        if cfg.factored and _is_factorable(p):
            return {"row": axes[:-1], "col": axes[:-2] + axes[-1:]}
        return axes

    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )
    return {
        "m": params_axes,
        "v": jax.tree_util.tree_map(v_axes, params_axes, params_values,
                                    is_leaf=is_axes),
        "count": (),
    }
