"""Sharded, asynchronous, integrity-checked checkpointing.

Two checkpointers share the idioms (atomic rename commit, sha256
integrity, keep-last-k retention):

* :class:`Checkpointer` — pytree/array state (training state).  Layout
  is one directory per step::

      <root>/step_00000100/
          shard_000.npz     # flattened (path -> array) leaves
          manifest.json     # treedef paths, shapes, dtypes, sha256s, metadata

  Features needed at 1000+ nodes, exercised single-process here:
    * async save off the critical path (background thread)
    * keep-last-k + keep-best retention
    * restore onto a DIFFERENT mesh / sharding (elastic rescale): leaves
      are saved as full (unsharded) arrays per-host shard-group and
      re-placed with the restore-time shardings
    * corruption detection via per-file sha256 in the manifest

* :class:`JsonCheckpointer` — JSON-document state (the tuning service's
  per-job snapshots).  Same commit discipline, stdlib-only: ``jax`` is
  imported lazily so worker daemons and the service can checkpoint on
  hosts that have no accelerator stack installed.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    import jax

    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, root: str, *, keep_last: int = 3, keep_best: int = 1,
                 async_save: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.keep_best = keep_best
        self.async_save = async_save
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._best: Dict[int, float] = {}  # step -> metric (higher better)

    # -- paths ---------------------------------------------------------------
    def _dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:08d}"

    def steps(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, metadata: Optional[dict] = None,
             metric: Optional[float] = None) -> None:
        import jax

        # materialize on host synchronously (cheap vs the write), write async
        flat = _flatten(jax.device_get(tree))
        meta = dict(metadata or {})
        meta.update({"step": step, "time": time.time()})
        if metric is not None:
            self._best[step] = float(metric)
            meta["metric"] = float(metric)
        if self.async_save:
            self.wait()
            self._pending = self._pool.submit(self._write, step, flat, meta)
        else:
            self._write(step, flat, meta)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], meta: dict) -> None:
        final = self._dir(step)
        tmp = self.root / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        shard_file = tmp / "shard_000.npz"
        np.savez(shard_file, **{k: v for k, v in flat.items()})
        digest = hashlib.sha256(shard_file.read_bytes()).hexdigest()
        manifest = {
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "files": {"shard_000.npz": digest},
            "metadata": meta,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        protected = set(steps[-self.keep_last:]) if self.keep_last else set()
        if self._best and self.keep_best:
            best = sorted(self._best, key=self._best.get, reverse=True)
            protected |= set(best[: self.keep_best])
        for s in steps:
            if s not in protected:
                shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: Optional[int], like: Any, *, shardings: Any = None):
        """Restore into the structure of ``like``; optionally place each leaf
        with ``shardings`` (a parallel pytree) — this is the elastic path:
        the target mesh may differ from the save-time mesh."""
        import jax

        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self._dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        blob = d / "shard_000.npz"
        digest = hashlib.sha256(blob.read_bytes()).hexdigest()
        if digest != manifest["files"]["shard_000.npz"]:
            raise IOError(f"checkpoint {d} corrupt: sha256 mismatch")
        data = np.load(blob)

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        paths = [jax.tree_util.keystr(p)
                 for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(paths))
        out = []
        for path, leaf_like, sh in zip(paths, leaves_like, shard_leaves):
            arr = data[path]
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return treedef.unflatten(out), manifest["metadata"]


class JsonCheckpointer:
    """Atomic, integrity-checked snapshots of a JSON document.

    The tuning service checkpoints each job's state (spec, status,
    history path) through this: every :meth:`save` writes
    ``snap_<seq>.json`` with an embedded sha256 over its payload and
    commits it by atomic rename, then prunes to ``keep_last``.
    :meth:`load` returns the newest snapshot that passes its integrity
    check — a snapshot truncated by the very crash being recovered from
    is skipped, and the previous good one restores instead.  Stdlib
    only; safe on hosts without the accelerator stack.
    """

    def __init__(self, root, *, keep_last: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = max(1, int(keep_last))

    def _seqs(self) -> List[int]:
        out = []
        for p in self.root.glob("snap_*.json"):
            try:
                out.append(int(p.stem.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def _path(self, seq: int) -> pathlib.Path:
        return self.root / f"snap_{seq:08d}.json"

    def save(self, doc: dict) -> int:
        """Snapshot ``doc``; returns the sequence number committed."""
        payload = json.dumps(doc, allow_nan=True, sort_keys=True)
        digest = hashlib.sha256(payload.encode("utf-8")).hexdigest()
        seqs = self._seqs()
        seq = (seqs[-1] + 1) if seqs else 0
        final = self._path(seq)
        tmp = final.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"sha256": digest, "time": time.time(), "doc": payload}))
        tmp.replace(final)  # atomic commit
        for old in seqs[: max(0, len(seqs) + 1 - self.keep_last)]:
            self._path(old).unlink(missing_ok=True)
        return seq

    def load(self) -> Optional[dict]:
        """Newest snapshot that passes its integrity check, or None."""
        for seq in reversed(self._seqs()):
            try:
                wrapper = json.loads(self._path(seq).read_text())
                payload = wrapper["doc"]
                digest = hashlib.sha256(
                    payload.encode("utf-8")).hexdigest()
                if digest != wrapper["sha256"]:
                    continue  # torn write: fall back to the previous snap
                return json.loads(payload)
            except (OSError, KeyError, ValueError, TypeError):
                continue
        return None
