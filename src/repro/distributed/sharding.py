"""Logical-axis sharding rules.

Parameters and activations carry *logical* axis names ("embed", "ff",
"heads", "vocab", "experts", "batch", "seq", ...).  A ``ShardingRules``
maps logical names to mesh axis names, dropping any assignment whose
dimension is not divisible by the mesh-axis size (e.g. qwen2's 14 heads on
a 16-way model axis are replicated rather than unevenly sharded).

Two rule families (both tunable by the paper-style tuner):

* ``tp``      — pure tensor-parallel: params shard over "model" only; the
                "data"/"pod" axes carry batch (classic DP+TP).
* ``fsdp_tp`` — additionally shards the params' "embed" dimension over
                "data" (ZeRO-3/FSDP style; XLA inserts the all-gathers).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# logical name -> candidate mesh axes (first whose size divides the dim wins;
# a tuple value means "shard over these mesh axes jointly").
def make_rules(style: str, multi_pod: bool) -> dict:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    rules = {
        "batch": batch_axes,
        "seq": ("model",),  # activations' seq dim: only for long-context/SP
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "cache_seq": ("model",),
        "state": ("model",),
        "layers": None,
        "head": None,
        "lora": None,
        "embed": ("data",) if style == "fsdp_tp" else None,
    }
    if style not in ("tp", "fsdp_tp"):
        raise ValueError(f"unknown sharding style {style!r}")
    return rules


class ShardingRules:
    def __init__(self, mesh: Mesh, style: str = "fsdp_tp", overrides: Optional[dict] = None):
        self.mesh = mesh
        self.style = style
        multi_pod = "pod" in mesh.axis_names
        self.rules = make_rules(style, multi_pod)
        if overrides:
            self.rules.update(overrides)

    def _axis_size(self, axis) -> int:
        if isinstance(axis, tuple):
            return int(np.prod([self.mesh.shape[a] for a in axis]))
        return int(self.mesh.shape[axis])

    def spec_for(
        self, logical_axes: Sequence[Optional[str]], shape: Optional[Tuple[int, ...]] = None
    ) -> PartitionSpec:
        """Resolve logical axes -> PartitionSpec, honouring divisibility."""
        out = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            assignment = None
            if name is not None:
                cand = self.rules.get(name)
                if cand is not None:
                    flat = cand if isinstance(cand, tuple) else (cand,)
                    # skip axes already used by another dim of this array
                    if not (set(flat) & used):
                        size = self._axis_size(cand)
                        if shape is None or shape[i] % size == 0:
                            # bare name for single axes: P("data"), not
                            # P(("data",)) — newer jax treats them as distinct
                            assignment = flat[0] if len(flat) == 1 else cand
                            used.update(flat)
            out.append(assignment)
        while out and out[-1] is None:
            out.pop()
        return PartitionSpec(*out)

    def sharding_for(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))

    def tree_specs(self, axes_tree, values_tree):
        """PartitionSpec pytree parallel to a params pytree."""
        return jax.tree_util.tree_map(
            lambda axes, v: self.spec_for(axes, tuple(v.shape)),
            axes_tree,
            values_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        )

    def tree_shardings(self, axes_tree, values_tree):
        return jax.tree_util.tree_map(
            lambda spec: NamedSharding(self.mesh, spec),
            self.tree_specs(axes_tree, values_tree),
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )


# ---------------------------------------------------------------------------
# Activation sharding hints inside model code
# ---------------------------------------------------------------------------

_ACTIVE = threading.local()


@contextlib.contextmanager
def active_rules(rules: Optional[ShardingRules]):
    prev = getattr(_ACTIVE, "rules", None)
    _ACTIVE.rules = rules
    try:
        yield
    finally:
        _ACTIVE.rules = prev


def shard_hint(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint against the active rules; no-op outside a
    distributed context (CPU smoke tests)."""
    rules: Optional[ShardingRules] = getattr(_ACTIVE, "rules", None)
    if rules is None:
        return x
    spec = rules.spec_for(logical_axes, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
