"""HLO text analysis: collective bytes + scan-aware cost extraction.

``compiled.cost_analysis()`` gives per-device HLO FLOPs/bytes;
collective traffic is NOT in cost_analysis, so we parse the (post-SPMD,
per-device) HLO text and sum operand bytes of every collective op,
multiplying ops inside ``while`` loop bodies by the loop trip count
(scan-over-layers!).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r"trip_count=(\d+)")
# e.g.: %fusion.1 = (f32[8,128]{1,0}, ...) all-gather(...)
_OP_RE = re.compile(r"=\s+(\([^)]*\)|\S+)\s+([\w-]+)(\.\d+)?\(")


def shape_bytes(shape_str: str) -> int:
    """Sum bytes over every tensor literal in an HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_kind: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        if not self.bytes_by_kind:
            return "none"
        parts = [
            f"{k}:{self.count_by_kind[k]}x/{self.bytes_by_kind[k]/1e6:.1f}MB"
            for k in sorted(self.bytes_by_kind)
        ]
        return " ".join(parts)


def _computation_blocks(hlo: str) -> Dict[str, str]:
    """Split HLO text into computation bodies keyed by computation name."""
    blocks: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        is_header = stripped.endswith("{") and "->" in stripped and "=" not in stripped.split("(")[0]
        if is_header:
            if cur_name is not None:
                blocks[cur_name] = "\n".join(cur_lines)
            name = stripped.split("(")[0].strip()
            if name.startswith("ENTRY"):
                name = name[len("ENTRY"):].strip()
            cur_name, cur_lines = name.lstrip("%").strip(), []
        elif stripped.startswith("}"):
            if cur_name is not None:
                blocks[cur_name] = "\n".join(cur_lines)
                cur_name, cur_lines = None, []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        blocks[cur_name] = "\n".join(cur_lines)
    return blocks


def _while_trip_counts(hlo: str) -> Dict[str, int]:
    """Map while-body computation name -> EFFECTIVE trip count (the product
    along the while-nesting chain: a layer scan inside a microbatch loop
    runs trips_layer x trips_mb times).

    XLA annotates `while` ops with backend_config known_trip_count after
    simplification."""
    own_trip: Dict[str, int] = {}
    edges: Dict[str, list] = defaultdict(list)  # enclosing block -> child bodies
    blocks = _computation_blocks(hlo)
    for name, text in blocks.items():
        for line in text.splitlines():
            if " while(" not in line:
                continue
            body = re.search(r"body=%?([\w\.\-_]+)", line)
            if not body:
                continue
            child = body.group(1)
            kt = re.search(r'"known_trip_count":\s*\{"n":"?(\d+)"?\}', line)
            if not kt:
                kt = _TRIP_RE.search(line)
            own_trip[child] = int(kt.group(1)) if kt else 1
            edges[name].append(child)
    # propagate multipliers down the nesting tree (roots: entry blocks)
    eff: Dict[str, int] = {}
    parents = {c: p for p, cs in edges.items() for c in cs}

    def mult_of(block: str) -> int:
        if block not in parents:  # reached an entry-level computation
            return 1
        p = parents[block]
        return own_trip.get(block, 1) * mult_of(p)

    for child in own_trip:
        eff[child] = mult_of(child)
    return eff


def collect_collective_stats(hlo: str) -> CollectiveStats:
    """Sum collective operand bytes, scaling while-body ops by trip count."""
    stats = CollectiveStats()
    blocks = _computation_blocks(hlo)
    trips = _while_trip_counts(hlo)

    def scan_block(text: str, multiplier: int):
        for line in text.splitlines():
            for kind in _COLLECTIVE_KINDS:
                # ops appear as `kind(`, `kind.N(`, or `kind-start(`
                if re.search(rf"=.*\s{kind}(?:-start)?(?:\.\d+)?\(", line):
                    # operand bytes = result shape bytes (collectives are
                    # shape-preserving except all-gather: use result which
                    # upper-bounds traffic) — take the shape on the lhs.
                    lhs = line.split("=", 1)[1] if "=" in line else line
                    shape_part = lhs.strip().split(" ", 1)[0]
                    b = shape_bytes(shape_part)
                    stats.bytes_by_kind[kind] += b * multiplier
                    stats.count_by_kind[kind] += multiplier
                    break

    # entry + all non-while computations count once; while bodies x trips
    for name, text in blocks.items():
        mult = trips.get(name, 1)
        scan_block(text, mult)
    return stats


_NO_TRAFFIC_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "optimization-barrier",
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^=]*?\)|\S+))\s+([\w\-]+)(?:\.\d+)?\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_META_RE = re.compile(r'op_name="([^"]*)"')


@dataclass
class TrafficStats:
    """Per-op HBM traffic accounting over the optimized per-device HLO.

    For every instruction in *executable* computations (entry + while
    bodies, the latter scaled by known trip counts; fusion internals are
    skipped — the fusion call-site's external operands/outputs count),
    traffic = output bytes + sum(operand bytes).  Pure-aliasing ops are
    skipped.  Ops whose metadata carries a ``krnl_`` scope (regions the
    Pallas kernels keep in VMEM on the real target) are bucketed
    separately so the roofline memory term can credit them with their
    true HBM traffic instead of the CPU-unfused op chain."""

    included_bytes: float = 0.0
    excluded_bytes: float = 0.0
    excluded_by_tag: Dict[str, float] = field(default_factory=lambda: defaultdict(float))


def traffic_analysis(hlo: str, exclude_substr: tuple = ("krnl_",)) -> TrafficStats:
    # pass 1: def table name -> bytes
    def_bytes: Dict[str, int] = {}
    for line in hlo.splitlines():
        m = _DEF_RE.match(line)
        if m:
            def_bytes[m.group(1)] = shape_bytes(m.group(2))

    blocks = _computation_blocks(hlo)
    trips = _while_trip_counts(hlo)
    # executable computations: ENTRY + while bodies/conds; fusion internals out
    while_bodies = set(trips)
    for line in hlo.splitlines():
        mb = re.search(r"while\(.*?body=%?([\w\.\-]+)", line)
        if mb:
            while_bodies.add(mb.group(1))
    exec_blocks = {}
    for name, text in blocks.items():
        if name in while_bodies:
            exec_blocks[name] = trips.get(name, 1)
        elif "ENTRY" in hlo and name in _entry_names(hlo):
            exec_blocks[name] = 1
    stats = TrafficStats()
    for name, mult in exec_blocks.items():
        for line in blocks[name].splitlines():
            m = _DEF_RE.match(line)
            if not m:
                continue
            out_name, shape_str, op_kind = m.groups()
            if op_kind in _NO_TRAFFIC_OPS or op_kind == "while":
                continue
            out_b = shape_bytes(shape_str)
            operand_b = 0
            args_part = line.split("(", 1)[1] if "(" in line else ""
            args_part = args_part.split("metadata=")[0]
            for om in _OPERAND_RE.finditer(args_part):
                operand_b += def_bytes.get(om.group(1), 0)
            total = (out_b + operand_b) * mult
            meta = _META_RE.search(line)
            tag = None
            if meta:
                for sub in exclude_substr:
                    idx = meta.group(1).find(sub)
                    if idx >= 0:
                        tag = meta.group(1)[idx:].split("/")[0]
                        break
            if tag:
                stats.excluded_bytes += total
                stats.excluded_by_tag[tag] += total
            else:
                stats.included_bytes += total
    return stats


def _entry_names(hlo: str) -> set:
    out = set()
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            out.add(m.group(1))
    return out


def cost_with_scan_correction(compiled, hlo: Optional[str] = None) -> Dict[str, float]:
    """compiled.cost_analysis() flops/bytes.  XLA's HloCostAnalysis already
    multiplies while-body cost by trip count when it is statically known
    (verified empirically in tests/test_hlo_analysis.py); this wrapper just
    normalizes key names across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": bytes_accessed, "raw": dict(ca)}
