"""Successive-halving rung scheduler for multi-fidelity tuning (ASHA).

The paper's dominant cost is the measurement itself: every probe pays a
full compile+measure cycle.  Most configurations can be rejected from a
cheap short measurement ("Auto-tuning TensorFlow Threading Model",
arXiv:1812.01665; AutoTVM, arXiv:1805.08166), so this module spends the
full measurement budget only on candidates that survive cheap screening:

* the **rung ladder** is a geometric fidelity schedule
  ``f_r = max_fidelity * eta^-(R-1-r)`` (e.g. eta=3, 3 rungs:
  1/9 -> 1/3 -> 1).  Fresh candidates enter at the bottom rung;
* **promotion** is asynchronous (ASHA, arXiv:1810.05934): there are no
  rung barriers — the moment a completed result sits in the top
  ``promote_quantile`` of its rung, it is eligible for resubmission at
  the next fidelity.  ``next_promotion`` scans rungs top-down so deeper
  (more informative) promotions win free workers first;
* a result outside the quantile simply stays where it is.  It is not
  discarded: rungs only grow, ``floor(n * quantile)`` grows with them,
  and a value can become promotable later once enough weaker results
  land below it;
* **preemption**: a promotion that is *in flight* when its source rung's
  cutoff rises above its own value is a dead man walking — its
  higher-fidelity measurement can no longer change the ranking it was
  promoted on.  ``dominated`` identifies such pendings so the driver can
  ``EvaluationExecutor.preempt`` them (cancelled if not yet started;
  recorded normally if a worker got there first — see executor docs for
  the exactly-once guarantee).

The scheduler is deliberately engine-agnostic: it talks in points and
values, sits between ``Tuner.run``'s async loop and the engine, and the
engine keeps seeing plain ``ask``/``tell`` — partial observations reach
BO as rows with a fidelity feature (see ``BayesOpt``), never as exact
values.  It implements the :class:`~repro.tuning.schedulers.base.
TrialScheduler` seam (and is also the inner per-bracket engine of
HyperBand); the historical entry points (``next_promotion`` /
``dominated`` / positional ``on_*``) remain public API.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.tuning.schedulers.base import (CONTINUE, PREEMPT, TrialAction,
                                          TrialScheduler)


@dataclass
class RungState:
    """Bookkeeping for one rung of the ladder."""

    fidelity: float
    #: completed (key, value) measurements at this fidelity
    results: List[Tuple[tuple, float]] = field(default_factory=list)
    #: keys currently promoted out of this rung (in flight or done above)
    promoted: set = field(default_factory=set)
    # counters for the bench/CI rung statistics
    n_started: int = 0
    n_completed: int = 0
    n_promoted: int = 0
    n_preempted: int = 0


class RungScheduler(TrialScheduler):
    """Completion-driven successive halving over an executor's pendings.

    ``eta`` is the reduction factor (fidelity ratio between adjacent
    rungs *and* the default survivor fraction); ``min_fidelity`` bounds
    the bottom rung (the ladder is the longest geometric schedule whose
    bottom stays >= ``min_fidelity``); ``promote_quantile`` is the
    per-rung survivor fraction (default ``1/eta``).
    """

    kind = "asha"

    def __init__(
        self,
        *,
        eta: float = 3.0,
        min_fidelity: float = 0.1,
        max_fidelity: float = 1.0,
        promote_quantile: Optional[float] = None,
    ):
        if eta <= 1.0:
            raise ValueError(f"eta must exceed 1 (got {eta})")
        if not 0.0 < min_fidelity <= max_fidelity <= 1.0:
            raise ValueError(
                f"need 0 < min_fidelity <= max_fidelity <= 1 "
                f"(got {min_fidelity}, {max_fidelity})")
        self.eta = float(eta)
        self.quantile = (1.0 / eta if promote_quantile is None
                         else float(promote_quantile))
        if not 0.0 < self.quantile < 1.0:
            raise ValueError(f"promote_quantile in (0,1) (got {self.quantile})")
        # longest geometric ladder with bottom >= min_fidelity
        n_down = int(math.floor(
            math.log(max_fidelity / min_fidelity) / math.log(eta) + 1e-9))
        fidelities = [max_fidelity * eta ** -(n_down - r)
                      for r in range(n_down)] + [max_fidelity]
        self.rungs: List[RungState] = [RungState(f) for f in fidelities]
        self._points: Dict[tuple, Dict] = {}  # key -> point (for resubmission)
        self._value_at: Dict[Tuple[tuple, int], float] = {}
        self._replayed: Set[Tuple[tuple, int]] = set()

    # -- ladder shape ---------------------------------------------------------
    @property
    def n_rungs(self) -> int:
        return len(self.rungs)

    def fidelity(self, rung: int) -> float:
        return self.rungs[rung].fidelity

    @property
    def base_fidelity(self) -> float:
        return self.rungs[0].fidelity

    def is_top(self, rung: int) -> bool:
        return rung == self.n_rungs - 1

    def rung_for(self, fidelity: float) -> int:
        """Closest rung for a delivered fidelity (ties go up).  Used to
        rebuild rung state from a resumed checkpoint, where only the
        recorded fidelity survives."""
        return min(range(self.n_rungs),
                   key=lambda r: (abs(self.rungs[r].fidelity - fidelity),
                                  -r))

    def replay(self, key: tuple, point: Dict, value: float, fidelity: float,
               *, rung: Optional[int] = None, lineage: Optional[str] = None,
               meta: Optional[dict] = None) -> float:
        """Rebuild state from a checkpointed completion (resume path).

        Records the result at the nearest rung and — crucially — re-marks
        the source rung's ``promoted`` set for results above the bottom
        rung: a rung-r result only ever exists because the key was
        promoted out of rung r-1, and without the mark a resumed run
        would re-promote (and re-measure, re-charge, re-record) it.
        Counters stay untouched beyond ``on_result``'s: stats describe
        *this* run's scheduling work, not the replayed prefix's.

        Returns the budget charged for the record.  Preempted
        placeholders never measured anything and a checkpoint written
        around a preemption race can hold *both* a preempted and a
        completed record for the same (key, rung) — both used to be
        charged (and double-ranked).  Replay now skips preempted
        records and dedupes on (key, rung), charging 0.0 for the skip.
        """
        if meta and meta.get("preempted"):
            return 0.0
        r = self.rung_for(fidelity) if rung is None else int(rung)
        r = min(max(r, 0), self.n_rungs - 1)
        if (key, r) in self._replayed:
            return 0.0
        self._replayed.add((key, r))
        self.on_result(key, point, value, r)
        if r > 0:
            self.rungs[r - 1].promoted.add(key)
        return float(fidelity)

    # -- TrialScheduler seam --------------------------------------------------
    def admit(self, key: tuple, point: Dict) -> Optional[TrialAction]:
        """Fresh candidates enter the bottom rung."""
        return TrialAction(point=dict(point), rung=0,
                           fidelity=self.base_fidelity, kind="start")

    def next_action(self) -> Optional[TrialAction]:
        nxt = self.next_promotion()
        if nxt is None:
            return None
        point, rung = nxt
        return TrialAction(point=point, rung=rung,
                           fidelity=self.fidelity(rung), kind="promote")

    def decide(self, key: tuple, rung: int,
               lineage: Optional[str] = None) -> str:
        return PREEMPT if self.dominated(key, rung) else CONTINUE

    # -- completion-driven protocol ------------------------------------------
    def on_started(self, key: tuple, point: Dict, rung: int,
                   lineage: Optional[str] = None) -> None:
        """A measurement for ``key`` was dispatched at ``rung``."""
        self._points[key] = dict(point)
        self.rungs[rung].n_started += 1

    def on_result(self, key: tuple, point: Dict, value: float, rung: int,
                  *, fidelity: Optional[float] = None,
                  meta: Optional[dict] = None,
                  lineage: Optional[str] = None) -> None:
        """A measurement completed at ``rung`` (any completion order)."""
        state = self.rungs[rung]
        state.results.append((key, float(value)))
        state.n_completed += 1
        self._points[key] = dict(point)
        self._value_at[(key, rung)] = float(value)

    def _cutoff(self, rung: int) -> Tuple[Optional[float], int]:
        """(weakest promotable value, k) at ``rung``; (None, 0) while the
        rung is too small to rank anything."""
        finite = sorted((v for _, v in self.rungs[rung].results
                         if math.isfinite(v)), reverse=True)
        k = int(len(self.rungs[rung].results) * self.quantile)
        if k <= 0 or not finite:
            return None, 0
        k = min(k, len(finite))
        return finite[k - 1], k

    def next_promotion(self) -> Optional[Tuple[Dict, int]]:
        """Best promotable (point, target_rung), deepest rung first, or
        ``None`` when no rung currently has a promotable survivor."""
        for rung in range(self.n_rungs - 2, -1, -1):
            state = self.rungs[rung]
            cut, _k = self._cutoff(rung)
            if cut is None:
                continue
            best_key, best_val = None, -math.inf
            for key, value in state.results:
                if (value >= cut and value > best_val
                        and key not in state.promoted
                        and math.isfinite(value)):
                    best_key, best_val = key, value
            if best_key is not None:
                state.promoted.add(best_key)
                state.n_promoted += 1
                return dict(self._points[best_key]), rung + 1
        return None

    def dominated(self, key: tuple, target_rung: int) -> bool:
        """True when an in-flight promotion *to* ``target_rung`` has been
        outclassed: its source-rung value fell below the source rung's
        current cutoff, so finishing the expensive measurement cannot be
        justified by the ranking that scheduled it.

        The cutoff is not strictly monotone — ``k = floor(n * quantile)``
        can increment on weak arrivals and pull the cutoff *down* — so a
        candidate preempted against a transiently high cutoff may become
        promotable again and be rescheduled.  That is churn, not lost
        work: a cancelled preemption measured nothing (see
        ``EvaluationExecutor.preempt``), so the retry is the candidate's
        first actual measurement at that rung."""
        if target_rung <= 0:  # bottom-rung entries carry no prior value
            return False
        src = target_rung - 1
        value = self._value_at.get((key, src))
        if value is None:
            return False
        cut, _k = self._cutoff(src)
        return cut is not None and value < cut

    def on_preempted(self, key: tuple, target_rung: int,
                     lineage: Optional[str] = None) -> None:
        """A promotion was cancelled before it started: return the key to
        its source rung's unpromoted pool (rungs grow, so it may become
        promotable again later).  The preemption is counted on the
        *target* rung — the rung whose ``n_started`` it cancels — so the
        per-rung stats reconcile: started = completed + preempted +
        still-in-flight."""
        if target_rung <= 0:
            return
        self.rungs[target_rung - 1].promoted.discard(key)
        self.rungs[target_rung].n_preempted += 1

    # -- observability --------------------------------------------------------
    def stats(self) -> List[dict]:
        """Per-rung counters for the bench/CI artifact."""
        return [
            {"rung": r, "fidelity": round(s.fidelity, 6),
             "started": s.n_started, "completed": s.n_completed,
             "promoted": s.n_promoted, "preempted": s.n_preempted}
            for r, s in enumerate(self.rungs)
        ]

    def snapshot(self) -> List[dict]:
        """Full per-rung *state* (stats + result/promotion sets), in
        JSON-able form.  The tuning service ships this over the wire in
        ``job_status`` replies, and the resume tests pin it equal between
        a crashed-and-replayed scheduler and a never-crashed one.  Keys
        (grid-key tuples) are rendered as lists for JSON."""
        return [
            dict(row,
                 results=sorted(([list(k), v] for k, v
                                 in self.rungs[row["rung"]].results),
                                key=repr),
                 promoted=sorted((list(k) for k
                                  in self.rungs[row["rung"]].promoted),
                                 key=repr))
            for row in self.stats()
        ]
