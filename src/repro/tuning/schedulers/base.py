"""The ``TrialScheduler`` seam: one driver loop, many allocation policies.

``Tuner._run_multi_fidelity`` used to *be* ASHA — the rung ladder's
promotion scan, preemption test and budget accounting were welded into
the async loop.  This package extracts the seam that loop and
``RungScheduler`` already implied, so HyperBand's bracket hedging and
PBT's exploit/explore forks plug into the *same* driver instead of
forking it.

Lifecycle contract (what the driver calls, in order)
----------------------------------------------------

1. ``replay(key, point, value, fidelity, ...)`` — once per checkpointed
   completion on resume, *before* the loop starts.  Returns the budget
   actually charged for the record (``0.0`` for duplicates and
   preempted placeholders), so resumed spend reconciles exactly once.
2. ``next_action()`` — while the executor has capacity: the scheduler's
   highest-priority follow-up work (an ASHA **promote**, a PBT next
   step or exploit/explore **fork**).  ``None`` means "nothing queued —
   offer me fresh candidates".
3. ``fresh_quota(capacity)`` / ``admit(key, point)`` — how many fresh
   engine candidates the scheduler will take, and the concrete
   :class:`TrialAction` (entry rung/fidelity/lineage) for each one.
   ``admit`` may return ``None`` to refuse a point (e.g. a full PBT
   population).
4. ``on_started(key, point, rung, lineage=...)`` — the action was
   dispatched to the executor.
5. ``decide(key, rung, lineage=...)`` — per in-flight task, each loop
   turn (only when preemption is enabled): ``"continue"`` or
   ``"preempt"``.  A ``"preempt"`` verdict goes to
   ``EvaluationExecutor.preempt``, which resolves the race three ways —
   ``cancelled`` (never started: the driver calls ``on_preempted``),
   ``running`` (let-it-finish: the verdict converges via that step's
   own ``on_result``) or ``done`` (completion won the race: recorded
   exactly once, never preempted).  The other two verdicts of the
   conceptual decide→{continue, promote, preempt, fork} lifecycle are
   spelled through ``next_action``: completion-driven schedulers don't
   interrupt a trial to promote or fork it, they queue the follow-up.
6. ``on_result(key, point, value, rung, fidelity=..., meta=...,
   lineage=...)`` — a measurement completed (any completion order).
   ``fidelity`` is what was actually delivered (budget accounting);
   ``meta`` may carry an evaluator ``fork_state`` checkpoint blob.
7. ``on_preempted(key, rung, lineage=...)`` — a ``decide``-issued
   preempt landed as ``cancelled``: nothing was measured.
8. ``stats()`` / ``snapshot()`` — observability: flat counter rows for
   bench/CI artifacts, and full JSON-able state for ``job_status`` and
   the resume-equality tests.

Exactly-once: the driver records a trial's history row iff
``on_result`` fired for it, and ``on_preempted`` fires only for the
``cancelled`` arm — a preempt that lands after the task completed is a
completion, not a preemption, for the scheduler too.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

#: ``decide`` verdicts.
CONTINUE = "continue"
PREEMPT = "preempt"


@dataclass
class TrialAction:
    """One unit of work the scheduler wants dispatched.

    ``rung`` is the scheduler's own coordinate for the trial (ASHA rung,
    HyperBand *global* rung = bracket offset + inner rung, PBT step
    index); the driver hands it back verbatim in ``on_result`` /
    ``decide`` / ``on_preempted``.  ``state`` is an opaque
    JSON-serializable evaluator checkpoint (``resume_state``) for
    checkpoint-fork schedulers; ``lineage`` names the trial's ancestry
    for History provenance, replay routing, and memo-key isolation of
    stateful steps.  ``kind`` is observability only ("start", "promote",
    "step", "fork").
    """

    point: Dict
    rung: int = 0
    fidelity: Optional[float] = None
    state: Optional[dict] = field(default=None, repr=False)
    lineage: Optional[str] = None
    kind: str = "start"


class TrialScheduler:
    """Base class: a no-op scheduler that admits everything at rung 0.

    Subclasses override the lifecycle hooks they care about; the base
    implementations are the degenerate "measure every candidate once at
    full fidelity" policy, so a subclass only implements its actual
    allocation logic.  See the module docstring for the full contract.
    """

    #: short policy name — config value, ``job_status`` display key
    kind: str = "trial"

    # -- admission ------------------------------------------------------------
    def fresh_quota(self, capacity: int) -> int:
        """How many *fresh* engine candidates to accept this turn (the
        driver never offers more than its free capacity)."""
        return capacity

    def admit(self, key: tuple, point: Dict) -> Optional[TrialAction]:
        """Entry action for a fresh candidate, or ``None`` to refuse it."""
        return TrialAction(point=dict(point))

    # -- scheduler-driven work ------------------------------------------------
    def next_action(self) -> Optional[TrialAction]:
        """Highest-priority queued follow-up (promotion / step / fork)."""
        return None

    # -- trial lifecycle ------------------------------------------------------
    def on_started(self, key: tuple, point: Dict, rung: int,
                   lineage: Optional[str] = None) -> None:
        pass

    def on_result(self, key: tuple, point: Dict, value: float, rung: int,
                  *, fidelity: Optional[float] = None,
                  meta: Optional[dict] = None,
                  lineage: Optional[str] = None) -> None:
        pass

    def decide(self, key: tuple, rung: int,
               lineage: Optional[str] = None) -> str:
        """``"continue"`` or ``"preempt"`` for an in-flight trial."""
        return CONTINUE

    def on_preempted(self, key: tuple, rung: int,
                     lineage: Optional[str] = None) -> None:
        pass

    # -- resume ---------------------------------------------------------------
    def replay(self, key: tuple, point: Dict, value: float, fidelity: float,
               *, rung: Optional[int] = None, lineage: Optional[str] = None,
               meta: Optional[dict] = None) -> float:
        """Rebuild state from one checkpointed completion; return the
        budget charged for it (0.0 when the record is a duplicate or a
        preempted placeholder)."""
        if meta and meta.get("preempted"):
            return 0.0
        return float(fidelity)

    # -- observability --------------------------------------------------------
    def stats(self) -> List[dict]:
        """Flat counter rows for bench/CI artifacts and status displays."""
        return []

    def snapshot(self):
        """Full JSON-able state (``job_status`` wire / resume equality)."""
        return self.stats()
