"""Population-Based Training over tuning configs (arXiv:1711.09846).

PBT keeps a steady-state **population** of trials stepping forever
(until the budget runs out): each completed step re-ranks the
population, the bottom quantile is culled, and every cull is replaced
by **exploit + explore** — clone a random top-quantile member (its
point *and* its evaluator checkpoint, the ``fork_state`` blob) and
perturb the clone's point.  Unlike ASHA/HyperBand there is no ladder:
the ``rung`` coordinate is the member's **step index**, every step runs
at one fixed ``step_fidelity``, and a trial's identity is its
``lineage`` (``m<k>``), not its point — the point *mutates* along the
lineage.

Checkpoint-fork protocol
------------------------

An evaluator that can continue a measurement from where a previous step
left off declares ``supports_fork = True``, accepts a ``resume_state=``
keyword (the blob a previous step returned as ``meta["fork_state"]``,
JSON-serializable — it rides the remote v2 task payload and the History
checkpoint), and returns the next blob in its own ``meta``.  Stateless
evaluators work too: every step is then an independent measurement of
the member's current point, which still gives exploit/explore over the
search space — just without warm-started measurements.

Exactly-once under preemption: a doomed member (culled while its step
is in flight) is preempted via ``decide()``.  If the preempt lands as
``cancelled`` the step measured nothing and ``on_preempted`` forks the
replacement; if the step completed first, its ``on_result`` sees the
doom mark and forks then.  Either way exactly one fork replaces the
member and the step is recorded at most once.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Set, Tuple

from repro.tuning.schedulers.base import (CONTINUE, PREEMPT, TrialAction,
                                          TrialScheduler)

_IDLE, _QUEUED, _RUNNING = "idle", "queued", "running"


class _Member:
    __slots__ = ("lineage", "point", "value", "state", "steps",
                 "status", "doomed", "parent")

    def __init__(self, lineage: str, point: Dict, *,
                 state: Optional[dict] = None, steps: int = 0,
                 value: Optional[float] = None,
                 parent: Optional[str] = None):
        self.lineage = lineage
        self.point = dict(point)
        self.value = value          # latest step's objective value
        self.state = state          # latest fork_state blob (opaque)
        self.steps = steps          # completed steps; next step's rung
        self.status = _IDLE
        self.doomed = False
        self.parent = parent


class PBTScheduler(TrialScheduler):
    """Steady-state exploit/explore population.

    ``space`` supplies the perturbation neighborhood (each dim's value
    list) and is only duck-typed (``dims`` with ``name``/``values``).
    ``exploit_quantile`` is both the cull fraction (bottom) and the
    donor pool fraction (top); ``perturb_prob`` is the per-dimension
    mutation probability (at least one dim always moves, or explore
    would be a no-op clone).
    """

    kind = "pbt"

    def __init__(
        self,
        space,
        *,
        population: int = 6,
        exploit_quantile: float = 0.25,
        perturb_prob: float = 0.25,
        step_fidelity: float = 1.0,
        seed: int = 0,
    ):
        if population < 2:
            raise ValueError(f"population must be >= 2 (got {population})")
        if not 0.0 < exploit_quantile < 0.5:
            raise ValueError(
                f"exploit_quantile in (0, 0.5) (got {exploit_quantile})")
        if not 0.0 < perturb_prob <= 1.0:
            raise ValueError(f"perturb_prob in (0, 1] (got {perturb_prob})")
        if not 0.0 < step_fidelity <= 1.0:
            raise ValueError(f"step_fidelity in (0, 1] (got {step_fidelity})")
        self._space = space
        self.population = int(population)
        self.exploit_quantile = float(exploit_quantile)
        self.perturb_prob = float(perturb_prob)
        self.step_fidelity = float(step_fidelity)
        self._rng = random.Random(int(seed) * 2654435761 % (2 ** 31) + 17)
        self._members: Dict[str, _Member] = {}   # insertion-ordered
        self._n_lineages = 0
        self._n_admitted = 0
        #: admission count at the last under-populated defer (see
        #: ``next_action``); None = not currently deferring
        self._deferred_at: Optional[int] = None
        self._replayed: Set[Tuple[str, int]] = set()
        self.n_forks = 0
        self.n_preempted = 0
        self.n_steps = 0

    # -- population ranking ---------------------------------------------------
    def _valued(self) -> List[_Member]:
        return [m for m in self._members.values() if m.value is not None]

    def _k(self, n: int) -> int:
        return max(1, int(n * self.exploit_quantile))

    def _bottom(self) -> List[_Member]:
        """Cull candidates: bottom quantile, only once the whole
        population has a value to rank (never cull against unknowns)."""
        if len(self._members) < self.population:
            return []
        valued = self._valued()
        if len(valued) < len(self._members):
            return []
        ranked = sorted(valued, key=lambda m: (m.value, m.lineage))
        return ranked[:self._k(len(ranked))]

    def _donor(self) -> Optional[_Member]:
        """A random top-quantile member (exploit source)."""
        valued = [m for m in self._valued() if not m.doomed]
        if not valued:
            return None
        ranked = sorted(valued, key=lambda m: (m.value, m.lineage),
                        reverse=True)
        return self._rng.choice(ranked[:self._k(len(ranked))])

    def _perturb(self, point: Dict) -> Dict:
        """Explore: mutate each dim with ``perturb_prob`` — numeric dims
        step to a neighboring grid value, categoricals resample.  At
        least one dim always moves."""
        new = dict(point)
        dims = [d for d in self._space.dims if len(list(d.values)) > 1]
        if not dims:
            return new
        moved = False
        for d in dims:
            if self._rng.random() >= self.perturb_prob:
                continue
            new[d.name] = self._neighbor(d, new.get(d.name))
            moved = True
        if not moved:
            d = self._rng.choice(dims)
            new[d.name] = self._neighbor(d, new.get(d.name))
        return new

    def _neighbor(self, dim, current):
        vals = list(dim.values)
        try:
            i = vals.index(current)
        except ValueError:
            i = None
        numeric = all(isinstance(v, (int, float))
                      and not isinstance(v, bool) for v in vals)
        if numeric and i is not None:
            j = i + (1 if self._rng.random() < 0.5 else -1)
            if not 0 <= j < len(vals):
                j = i - (j - i)
            return vals[j]
        j = self._rng.randrange(len(vals))
        if i is not None and j == i:
            j = (j + 1) % len(vals)
        return vals[j]

    def _fork_from(self, donor: _Member) -> _Member:
        lin = f"m{self._n_lineages}"
        self._n_lineages += 1
        child = _Member(lin, self._perturb(donor.point),
                        state=donor.state, steps=donor.steps,
                        value=None, parent=donor.lineage)
        self._members[lin] = child
        self.n_forks += 1
        return child

    def _replace(self, member: _Member) -> Optional[_Member]:
        donor = self._donor()
        if donor is None or donor.lineage == member.lineage:
            return None
        self._members.pop(member.lineage, None)
        return self._fork_from(donor)

    # -- TrialScheduler seam --------------------------------------------------
    def fresh_quota(self, capacity: int) -> int:
        """Fresh engine candidates only seed the initial population;
        afterwards all new blood arrives by exploit/explore forks."""
        return max(0, min(capacity, self.population - len(self._members)))

    def admit(self, key: tuple, point: Dict) -> Optional[TrialAction]:
        if len(self._members) >= self.population:
            return None
        lin = f"m{self._n_lineages}"
        self._n_lineages += 1
        self._n_admitted += 1
        self._deferred_at = None  # admission works: keep preferring it
        self._members[lin] = _Member(lin, point)
        return self._action(self._members[lin], kind="start")

    def _action(self, member: _Member, kind: str = "step") -> TrialAction:
        member.status = _QUEUED
        return TrialAction(point=dict(member.point), rung=member.steps,
                           fidelity=self.step_fidelity, state=member.state,
                           lineage=member.lineage, kind=kind)

    def next_action(self) -> Optional[TrialAction]:
        if len(self._members) < self.population:
            # under-populated: yield the capacity to fresh admission
            # (the driver only asks the engine with what next_action
            # left over, so stepping now would starve the seeding).
            # If a whole driver cycle passes with no admission at all —
            # engine exhausted, every candidate a duplicate — stop
            # waiting and step the members we have.
            if self._deferred_at != self._n_admitted:
                self._deferred_at = self._n_admitted
                return None
        # a replayed checkpoint may resurrect culled lineages: shed the
        # weakest idle extras before stepping anyone
        while len(self._members) > self.population:
            idle = [m for m in self._members.values() if m.status == _IDLE]
            if not idle:
                break
            worst = min(idle, key=lambda m: (m.value is not None,
                                             m.value if m.value is not None
                                             else 0.0, m.lineage))
            self._members.pop(worst.lineage)
        bottom = {m.lineage for m in self._bottom()}
        # least-stepped idle member first: the population advances in
        # rough lockstep, so ranking always compares peers (a member
        # allowed to run ahead would win on accumulated steps alone)
        order = {lin: i for i, lin in enumerate(self._members)}
        idle = sorted((m for m in self._members.values()
                       if m.status == _IDLE),
                      key=lambda m: (m.steps, order[m.lineage]))
        for member in idle:
            if member.value is not None and member.lineage in bottom:
                forked = self._replace(member)
                if forked is not None:
                    return self._action(forked, kind="fork")
            return self._action(member)
        return None

    def on_started(self, key: tuple, point: Dict, rung: int,
                   lineage: Optional[str] = None) -> None:
        member = self._members.get(lineage or "")
        if member is not None:
            member.status = _RUNNING

    def on_result(self, key: tuple, point: Dict, value: float, rung: int,
                  *, fidelity: Optional[float] = None,
                  meta: Optional[dict] = None,
                  lineage: Optional[str] = None) -> None:
        self.n_steps += 1
        member = self._members.get(lineage or "")
        if member is None:
            return  # step of a lineage culled while racing; value recorded
        member.status = _IDLE
        member.value = float(value)
        member.steps = max(member.steps, int(rung) + 1)
        if meta and meta.get("fork_state") is not None:
            member.state = meta["fork_state"]
        if member.doomed:  # culled while running; fork now, exactly once
            member.doomed = False
            self._replace(member)
            return
        # re-rank: doom in-flight bottom-quantile members so decide()
        # preempts their (now pointless) steps
        for m in self._bottom():
            if m.status == _RUNNING:
                m.doomed = True

    def decide(self, key: tuple, rung: int,
               lineage: Optional[str] = None) -> str:
        member = self._members.get(lineage or "")
        if member is not None and member.doomed and member.status == _RUNNING:
            return PREEMPT
        return CONTINUE

    def on_preempted(self, key: tuple, rung: int,
                     lineage: Optional[str] = None) -> None:
        """The doomed member's step was cancelled unstarted: fork its
        replacement immediately (the other arm of the race is
        ``on_result``'s doom check)."""
        self.n_preempted += 1
        member = self._members.get(lineage or "")
        if member is None:
            return
        member.status = _IDLE
        member.doomed = False
        self._replace(member)

    def replay(self, key: tuple, point: Dict, value: float, fidelity: float,
               *, rung: Optional[int] = None, lineage: Optional[str] = None,
               meta: Optional[dict] = None) -> float:
        """Rebuild the population from checkpointed steps.  The latest
        step per lineage wins (point/value/fork_state); duplicates of
        one (lineage, step) and preempted placeholders charge nothing."""
        if meta and meta.get("preempted"):
            return 0.0
        lin = lineage or "m?"
        step = 0 if rung is None else int(rung)
        if (lin, step) in self._replayed:
            return 0.0
        self._replayed.add((lin, step))
        member = self._members.get(lin)
        if member is None:
            member = self._members[lin] = _Member(lin, point)
            if lin.startswith("m"):
                try:
                    self._n_lineages = max(self._n_lineages,
                                           int(lin[1:]) + 1)
                except ValueError:
                    pass
        if step + 1 >= member.steps or member.value is None:
            member.point = dict(point)
            member.value = float(value)
            member.steps = max(member.steps, step + 1)
            if meta and meta.get("fork_state") is not None:
                member.state = meta["fork_state"]
        return float(fidelity)

    # -- observability --------------------------------------------------------
    def stats(self) -> List[dict]:
        values = sorted(m.value for m in self._valued())
        n = len(values)
        median = (None if n == 0 else
                  values[n // 2] if n % 2 else
                  0.5 * (values[n // 2 - 1] + values[n // 2]))
        return [{
            "members": len(self._members),
            "steps": self.n_steps,
            "forks": self.n_forks,
            "preempted": self.n_preempted,
            "best": max(values) if values else None,
            "median": median,
        }]

    def snapshot(self) -> dict:
        return {
            "population": self.population,
            "forks": self.n_forks,
            "preempted": self.n_preempted,
            "steps": self.n_steps,
            "members": [
                {"lineage": m.lineage, "point": dict(m.point),
                 "value": m.value, "steps": m.steps, "status": m.status,
                 "doomed": m.doomed, "parent": m.parent,
                 "has_state": m.state is not None}
                for m in self._members.values()
            ],
        }
