"""Scheduler zoo: budget-allocation policies behind one driver seam.

``Tuner._run_multi_fidelity`` is a scheduler-agnostic async driver; the
policy deciding *which trial gets the next worker slot and at what
fidelity* lives here, behind :class:`TrialScheduler`:

* :class:`RungScheduler` (``asha``) — successive halving on one
  geometric fidelity ladder; the default and the golden-traced policy;
* :class:`HyperBandScheduler` (``hyperband``) — several ASHA brackets
  with staggered min-fidelities, hedging against uninformative cheap
  measurements, budget split completion-driven;
* :class:`PBTScheduler` (``pbt``) — steady-state population with
  exploit/explore forks and evaluator checkpoint-fork support.

``build_scheduler`` maps a ``MultiFidelityConfig`` to an instance.
"""
from __future__ import annotations

from repro.tuning.schedulers.asha import RungScheduler, RungState
from repro.tuning.schedulers.base import (CONTINUE, PREEMPT, TrialAction,
                                          TrialScheduler)
from repro.tuning.schedulers.hyperband import HyperBandScheduler
from repro.tuning.schedulers.pbt import PBTScheduler

SCHEDULER_KINDS = ("asha", "hyperband", "pbt")


def build_scheduler(mf, *, space=None, seed: int = 0) -> TrialScheduler:
    """Instantiate the scheduler a ``MultiFidelityConfig`` names.

    ``space`` is required for PBT (the perturbation neighborhood);
    ``seed`` makes PBT's exploit/explore draws reproducible.
    """
    kind = getattr(mf, "scheduler", "asha") or "asha"
    if kind == "asha":
        return RungScheduler(eta=mf.eta, min_fidelity=mf.min_fidelity,
                             promote_quantile=mf.promote_quantile)
    if kind == "hyperband":
        hb = getattr(mf, "hyperband", None)
        return HyperBandScheduler(
            eta=mf.eta, min_fidelity=mf.min_fidelity,
            promote_quantile=mf.promote_quantile,
            brackets=getattr(hb, "brackets", None))
    if kind == "pbt":
        if space is None:
            raise ValueError("PBT needs the search space for explore")
        pbt = getattr(mf, "pbt", None)
        step = getattr(pbt, "step_fidelity", None)
        return PBTScheduler(
            space,
            population=getattr(pbt, "population", 6),
            exploit_quantile=getattr(pbt, "exploit_quantile", 0.25),
            perturb_prob=getattr(pbt, "perturb_prob", 0.25),
            step_fidelity=float(step) if step else mf.min_fidelity,
            seed=seed)
    raise ValueError(
        f"unknown scheduler {kind!r} (expected one of {SCHEDULER_KINDS})")


__all__ = [
    "CONTINUE", "PREEMPT", "SCHEDULER_KINDS", "TrialAction", "TrialScheduler",
    "RungScheduler", "RungState", "HyperBandScheduler", "PBTScheduler",
    "build_scheduler",
]
