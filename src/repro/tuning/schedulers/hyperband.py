"""HyperBand: bracket hedging over ASHA ladders (arXiv:1603.06560).

ASHA's single ladder bakes in one answer to "how cheap can a screening
measurement be before its ranking stops predicting the full-fidelity
ranking?".  When low fidelities are informative, a deep ladder wins by
screening widely; when they are noise, a shallow ladder (or plain full
measurement) wins by not wasting budget on them.  HyperBand hedges:
run several brackets — ASHA ladders with *staggered* minimum
fidelities, from the deepest geometric ladder down to a single
full-fidelity rung — and split the measurement budget across them.

This implementation keeps the substrate completion-driven (no
synchronized bracket rounds, matching our ASHA):

* each bracket ``s`` (``s = s_max .. 0``) is an inner
  :class:`RungScheduler` with ``min_fidelity = max_fidelity * eta^-s``;
  ``s = 0`` degenerates to one full-fidelity rung;
* **budget split is completion-driven**: a fresh candidate is admitted
  to the bracket with the least fidelity-spend so far, so brackets
  converge to equal budget shares (HyperBand's ``B/(s_max+1)``) without
  a precomputed schedule.  Spend is charged at dispatch (the ladder
  fidelity), trued-up to the delivered fidelity at completion, and
  refunded on a cancelled preemption;
* promotions are served from the *least-spent* bracket first, so a
  bracket that fell behind (e.g. all its trials were preempted) catches
  up the moment it has promotable work;
* trials carry their bracket as ``lineage="b<idx>"`` (History
  provenance + replay routing) and a **global rung id** = bracket
  offset + inner rung, so the driver and executor stay
  bracket-oblivious.  Results themselves are stateless and keyed by
  (point, fidelity) alone — two brackets that measure the same point at
  the same fidelity share the memo hit, which is a feature, not a
  collision.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.tuning.schedulers.asha import RungScheduler
from repro.tuning.schedulers.base import (CONTINUE, PREEMPT, TrialAction,
                                          TrialScheduler)


class HyperBandScheduler(TrialScheduler):
    """Multiple ASHA brackets with staggered min-fidelities.

    ``brackets`` caps how many ladders to run (default: the full
    ``s_max + 1`` the fidelity range supports; always the *deepest*
    ladders first, since the shallow ones are subsets).
    """

    kind = "hyperband"

    def __init__(
        self,
        *,
        eta: float = 3.0,
        min_fidelity: float = 0.1,
        max_fidelity: float = 1.0,
        promote_quantile: Optional[float] = None,
        brackets: Optional[int] = None,
    ):
        # the deepest ladder the fidelity range supports fixes s_max
        deepest = RungScheduler(eta=eta, min_fidelity=min_fidelity,
                                max_fidelity=max_fidelity,
                                promote_quantile=promote_quantile)
        s_max = deepest.n_rungs - 1
        n = s_max + 1 if brackets is None else int(brackets)
        if not 1 <= n <= s_max + 1:
            raise ValueError(
                f"brackets must be in [1, {s_max + 1}] for "
                f"min_fidelity={min_fidelity} (got {brackets})")
        self.eta = float(eta)
        self.brackets: List[RungScheduler] = [deepest]
        for s in range(s_max - 1, s_max - n, -1):
            self.brackets.append(RungScheduler(
                eta=eta,
                min_fidelity=max_fidelity * eta ** -s if s else max_fidelity,
                max_fidelity=max_fidelity,
                promote_quantile=promote_quantile))
        # global rung id = bracket offset + inner rung
        self._offsets: List[int] = []
        off = 0
        for b in self.brackets:
            self._offsets.append(off)
            off += b.n_rungs
        self._spend: List[float] = [0.0] * len(self.brackets)

    # -- bracket plumbing -----------------------------------------------------
    def _locate(self, rung: int) -> tuple:
        """Global rung id -> (bracket index, inner rung)."""
        for i in range(len(self.brackets) - 1, -1, -1):
            if rung >= self._offsets[i]:
                inner = min(rung - self._offsets[i],
                            self.brackets[i].n_rungs - 1)
                return i, inner
        return 0, 0

    def _bracket_of(self, lineage: Optional[str],
                    rung: Optional[int]) -> Optional[int]:
        """Replay routing: lineage ("b<idx>") first, global rung second."""
        if lineage and lineage.startswith("b"):
            try:
                i = int(lineage[1:])
                if 0 <= i < len(self.brackets):
                    return i
            except ValueError:
                pass
        if rung is not None:
            return self._locate(int(rung))[0]
        return None

    @property
    def base_fidelity(self) -> float:
        return self.brackets[0].base_fidelity

    # -- TrialScheduler seam --------------------------------------------------
    def admit(self, key: tuple, point: Dict) -> Optional[TrialAction]:
        """Fresh candidates feed the least-spent bracket (completion-
        driven budget split: brackets equalize spend asymptotically)."""
        i = min(range(len(self.brackets)), key=lambda j: (self._spend[j], j))
        b = self.brackets[i]
        self._spend[i] += b.base_fidelity  # planned; trued-up at on_result
        return TrialAction(point=dict(point), rung=self._offsets[i],
                           fidelity=b.base_fidelity,
                           lineage=f"b{i}", kind="start")

    def next_action(self) -> Optional[TrialAction]:
        for i in sorted(range(len(self.brackets)),
                        key=lambda j: (self._spend[j], j)):
            nxt = self.brackets[i].next_promotion()
            if nxt is None:
                continue
            point, inner = nxt
            fid = self.brackets[i].fidelity(inner)
            self._spend[i] += fid  # planned; trued-up at on_result
            return TrialAction(point=point, rung=self._offsets[i] + inner,
                               fidelity=fid, lineage=f"b{i}", kind="promote")
        return None

    def on_started(self, key: tuple, point: Dict, rung: int,
                   lineage: Optional[str] = None) -> None:
        i, inner = self._locate(rung)
        self.brackets[i].on_started(key, point, inner)

    def on_result(self, key: tuple, point: Dict, value: float, rung: int,
                  *, fidelity: Optional[float] = None,
                  meta: Optional[dict] = None,
                  lineage: Optional[str] = None) -> None:
        i, inner = self._locate(rung)
        b = self.brackets[i]
        if fidelity is not None:  # true up planned -> delivered spend
            self._spend[i] += float(fidelity) - b.fidelity(inner)
        b.on_result(key, point, value, inner)

    def decide(self, key: tuple, rung: int,
               lineage: Optional[str] = None) -> str:
        i, inner = self._locate(rung)
        return PREEMPT if self.brackets[i].dominated(key, inner) else CONTINUE

    def on_preempted(self, key: tuple, rung: int,
                     lineage: Optional[str] = None) -> None:
        i, inner = self._locate(rung)
        self._spend[i] -= self.brackets[i].fidelity(inner)  # measured nothing
        self.brackets[i].on_preempted(key, inner)

    def replay(self, key: tuple, point: Dict, value: float, fidelity: float,
               *, rung: Optional[int] = None, lineage: Optional[str] = None,
               meta: Optional[dict] = None) -> float:
        if meta and meta.get("preempted"):
            return 0.0
        i = self._bracket_of(lineage, rung)
        if i is None:  # pre-lineage checkpoint: deepest ladder hosts it
            i = 0
        inner = (self.brackets[i].rung_for(fidelity) if rung is None
                 else min(max(int(rung) - self._offsets[i], 0),
                          self.brackets[i].n_rungs - 1))
        charged = self.brackets[i].replay(key, point, value, fidelity,
                                          rung=inner, meta=meta)
        self._spend[i] += charged
        return charged

    # -- observability --------------------------------------------------------
    def stats(self) -> List[dict]:
        """Per-rung rows across all brackets; rungs are *global* ids and
        every row names its bracket, so generic rung renderers still
        work and bracket-aware ones can group."""
        rows = []
        for i, b in enumerate(self.brackets):
            for row in b.stats():
                rows.append(dict(row, rung=self._offsets[i] + row["rung"],
                                 bracket=i))
        return rows

    def snapshot(self) -> dict:
        return {
            "brackets": [
                {"bracket": i,
                 "min_fidelity": round(b.base_fidelity, 6),
                 "spend": round(self._spend[i], 6),
                 "rungs": b.snapshot()}
                for i, b in enumerate(self.brackets)
            ],
        }
