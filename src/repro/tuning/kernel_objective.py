"""Kernel-autotuning objective — tune the repo's *own* Pallas kernels.

The paper tunes a real framework backend; this module closes the same
loop for the repo's kernels: the search space is the Pallas tile/grid
knobs each kernel actually takes (``block_q``, ``block_kv``,
``block_rows``, ``chunk``, ``block_d``), the measurement is the shared
variance-adaptive :class:`~repro.tuning.evaluator.WallClockEvaluator`
loop, and the product is a best-known config per (kernel, shape bucket,
hardware) persisted in :class:`~repro.tuning.tundb.TuningDB` by
``benchmarks/kernel_sweep.py``.

Two measurement modes:

* **in-process** (default) — the kernel runs through the public
  ``repro.kernels.ops`` dispatch with ``impl="pallas"`` (interpret mode
  on CPU, the real kernel on TPU).  Cheap enough for CI smoke; relative
  tile rankings on CPU-interpret are a proxy, real timing is the
  ``slow``-gated TPU path.
* **subprocess** — for the *host-level* knobs of the SNIPPETS.md
  exemplars (``--xla_force_host_platform_device_count``, extra
  ``XLA_FLAGS``) that cannot change inside a live process: jax reads
  ``XLA_FLAGS`` once at first import, so points carrying host knobs are
  measured by re-invoking ``python -m repro.tuning.kernel_objective``
  with the flags in the child environment (the paper's
  fresh-process-per-measurement harness).  Orders of magnitude more
  expensive per point; gated ``slow`` in tests.

Point hygiene mirrors the ``config_from_point`` fix: a point key that
is neither a knob of the targeted kernel nor a recognized host knob
raises ``ValueError`` — a typo'd dim must never silently tune nothing.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys
from typing import Dict, Optional, Tuple

from repro.tuning.objective import Evaluator

#: host-level knobs (subprocess-only; see module docstring)
HOST_KNOBS = ("host_devices", "xla_flags")

#: XLA_FLAGS presets worth trying on a CPU host (exemplar-derived)
XLA_FLAG_PRESETS = (
    "",
    "--xla_cpu_multi_thread_eigen=true",
    "--xla_cpu_multi_thread_eigen=false",
)


def _pow2_choices(lo: int, hi: int) -> "list[int]":
    v, out = lo, []
    while v <= hi:
        out.append(v)
        v *= 2
    return out or [lo]


# ---------------------------------------------------------------------------
# Kernel registry: shapes, tunable knobs, search space, step builders
# ---------------------------------------------------------------------------


class KernelSpec:
    """One tunable kernel: its call-shape dims, knob names, search
    space, and a ``WallClockEvaluator``-style step builder."""

    def __init__(self, name: str, shape: Dict[str, int], knobs: tuple,
                 space_fn, build_fn, examples_fn):
        self.name = name
        self.shape = dict(shape)
        self.knobs = tuple(knobs)
        self._space_fn = space_fn
        self._build_fn = build_fn
        self._examples_fn = examples_fn

    def space(self, shape: Optional[Dict[str, int]] = None) -> "list[dict]":
        return self._space_fn(dict(self.shape if shape is None else shape))

    def build(self, shape: Dict[str, int], point: Dict):
        """-> (step_fn, args, examples_per_step) for WallClockEvaluator."""
        stray = sorted(k for k in point if k not in self.knobs)
        if stray:
            raise ValueError(
                f"point keys {stray} are not knobs of kernel "
                f"{self.name!r} (knobs: {sorted(self.knobs)})")
        step, args = self._build_fn(shape, point)
        return step, args, float(self._examples_fn(shape))


def _attn_space(s):
    return [
        {"name": "block_q", "type": "cat",
         "choices": _pow2_choices(8, max(8, s["Sq"]))},
        {"name": "block_kv", "type": "cat",
         "choices": _pow2_choices(8, max(8, s["Sk"]))},
    ]


def _build_flash(s, point):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (s["B"], s["Sq"], s["H"], s["dh"]), jnp.float32)
    k = jax.random.normal(kk, (s["B"], s["Sk"], s["K"], s["dh"]), jnp.float32)
    v = jax.random.normal(kv, (s["B"], s["Sk"], s["K"], s["dh"]), jnp.float32)
    bq = int(point.get("block_q", 128))
    bkv = int(point.get("block_kv", 128))

    def step(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="pallas",
                             block_q=bq, block_kv=bkv)

    return step, (q, k, v)


def _decode_space(s):
    return [{"name": "block_kv", "type": "cat",
             "choices": _pow2_choices(8, max(8, s["Smax"]))}]


def _build_decode(s, point):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (s["B"], s["H"], s["dh"]), jnp.float32)
    k = jax.random.normal(kk, (s["B"], s["Smax"], s["K"], s["dh"]), jnp.float32)
    v = jax.random.normal(kv, (s["B"], s["Smax"], s["K"], s["dh"]), jnp.float32)
    lengths = jnp.full((s["B"],), s["Smax"] // 2, jnp.int32)
    bkv = int(point.get("block_kv", 512))

    def step(q, k, v, lengths):
        return ops.decode_attention(q, k, v, lengths, impl="pallas",
                                    block_kv=bkv)

    return step, (q, k, v, lengths)


def _rms_space(s):
    return [{"name": "block_rows", "type": "cat",
             "choices": _pow2_choices(8, max(8, s["rows"]))}]


def _build_rms(s, point):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(0), (s["rows"], s["D"]),
                          jnp.float32)
    scale = jnp.ones((s["D"],), jnp.float32)
    br = int(point.get("block_rows", 256))

    def step(x, scale):
        return ops.rmsnorm(x, scale, impl="pallas", block_rows=br)

    return step, (x, scale)


def _ssm_space(s):
    return [
        {"name": "chunk", "type": "cat",
         "choices": _pow2_choices(8, max(8, s["S"]))},
        {"name": "block_d", "type": "cat",
         "choices": _pow2_choices(8, max(8, s["D"]))},
    ]


def _build_ssm(s, point):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, D, N = s["B"], s["S"], s["D"], s["N"]
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (D, N), jnp.float32))
    B_in = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    C_in = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    D_skip = jnp.ones((D,), jnp.float32)
    chunk = int(point.get("chunk", 128))
    bd = int(point.get("block_d", 256))

    def step(x, dt, A, B_in, C_in, D_skip):
        return ops.ssm_scan(x, dt, A, B_in, C_in, D_skip, impl="pallas",
                            chunk=chunk, block_d=bd)

    return step, (x, dt, A, B_in, C_in, D_skip)


def _gla_space(s):
    return [{"name": "chunk", "type": "cat",
             "choices": _pow2_choices(8, max(8, s["S"]))}]


def _build_gla(s, point):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, dk, dv = s["B"], s["S"], s["H"], s["dk"], s["dv"]
    r = jax.random.normal(ks[0], (B, S, H, dk), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, dk), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, dv), jnp.float32)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, dk), jnp.float32)))
    u = jax.random.normal(ks[4], (H, dk), jnp.float32)
    chunk = int(point.get("chunk", 64))

    def step(r, k, v, w, u):
        return ops.gla_scan(r, k, v, w, u, impl="pallas", chunk=chunk)

    return step, (r, k, v, w, u)


#: tiny interpret-mode-friendly default shapes; real-timing sweeps pass
#: production shapes explicitly
KERNELS: Dict[str, KernelSpec] = {
    "flash_attention": KernelSpec(
        "flash_attention",
        {"B": 2, "Sq": 64, "Sk": 64, "H": 2, "K": 2, "dh": 16},
        ("block_q", "block_kv"), _attn_space, _build_flash,
        lambda s: s["B"] * s["Sq"]),
    "decode_attention": KernelSpec(
        "decode_attention",
        {"B": 2, "H": 2, "K": 2, "dh": 16, "Smax": 64},
        ("block_kv",), _decode_space, _build_decode,
        lambda s: s["B"]),
    "rmsnorm": KernelSpec(
        "rmsnorm",
        {"rows": 128, "D": 128},
        ("block_rows",), _rms_space, _build_rms,
        lambda s: s["rows"]),
    "ssm_scan": KernelSpec(
        "ssm_scan",
        {"B": 2, "S": 64, "D": 32, "N": 8},
        ("chunk", "block_d"), _ssm_space, _build_ssm,
        lambda s: s["B"] * s["S"]),
    "gla_scan": KernelSpec(
        "gla_scan",
        {"B": 2, "S": 64, "H": 2, "dk": 16, "dv": 16},
        ("chunk",), _gla_space, _build_gla,
        lambda s: s["B"] * s["S"]),
}


def kernel_space(kernel: str, shape: Optional[Dict[str, int]] = None,
                 *, host_knobs: bool = False) -> "list[dict]":
    """SearchSpace dims for one kernel (optionally + host-level knobs).

    ``host_knobs=True`` appends the SNIPPETS.md exemplar knobs
    (``host_devices`` → ``--xla_force_host_platform_device_count``,
    ``xla_flags`` presets); those points require an evaluator with
    ``allow_subprocess=True``.
    """
    dims = KERNELS[kernel].space(shape)
    if host_knobs:
        ncpu = os.cpu_count() or 1
        dims += [
            {"name": "host_devices", "type": "cat",
             "choices": [n for n in (1, 2, 4, 8) if n <= ncpu] or [1]},
            {"name": "xla_flags", "type": "cat",
             "choices": list(XLA_FLAG_PRESETS)},
        ]
    return dims


# ---------------------------------------------------------------------------
# Evaluator
# ---------------------------------------------------------------------------


class KernelTuneEvaluator(Evaluator):
    """Measured throughput (examples/s) of one Pallas kernel at one shape.

    Implements the evaluator protocol incl. fidelity by delegating to
    :class:`~repro.tuning.evaluator.WallClockEvaluator`; a full-fidelity
    call is byte-identical to a plain call (golden-trace contract).

    Points carrying host knobs (``host_devices``, ``xla_flags``) are
    measured in a fresh subprocess with ``XLA_FLAGS`` set in the child
    environment — iff ``allow_subprocess=True``; otherwise they raise,
    because a live process cannot re-read ``XLA_FLAGS``.
    """

    supports_fidelity = True

    def __init__(self, kernel: str, shape: Optional[Dict[str, int]] = None,
                 *, warmup: int = 1, iters: int = 3, adaptive: bool = True,
                 rel_halfwidth: float = 0.2,
                 allow_subprocess: bool = False, timeout: float = 300.0):
        if kernel not in KERNELS:
            raise ValueError(
                f"unknown kernel {kernel!r}; one of {sorted(KERNELS)}")
        self.kernel = kernel
        self.spec = KERNELS[kernel]
        self.shape = dict(self.spec.shape if shape is None else shape)
        self.allow_subprocess = allow_subprocess
        self.timeout = float(timeout)
        self._harness = dict(warmup=warmup, iters=iters, adaptive=adaptive,
                             rel_halfwidth=rel_halfwidth)
        # lazy import keeps this module importable without jax on the
        # harness side (the subprocess child imports it before jax init)
        from repro.tuning.evaluator import WallClockEvaluator

        self._wall = WallClockEvaluator(
            self._make_step, warmup=warmup, iters=iters, adaptive=adaptive,
            rel_halfwidth=rel_halfwidth)

    def _make_step(self, point: Dict):
        return self.spec.build(self.shape, point)

    def __call__(self, point: Dict,
                 fidelity: Optional[float] = None) -> Tuple[float, dict]:
        host = {k: point[k] for k in HOST_KNOBS if k in point}
        tile = {k: v for k, v in point.items() if k not in HOST_KNOBS}
        if host:
            if not self.allow_subprocess:
                raise ValueError(
                    f"point carries host knobs {sorted(host)} but this "
                    "evaluator was built with allow_subprocess=False — "
                    "XLA_FLAGS cannot change inside a live process; build "
                    "KernelTuneEvaluator(..., allow_subprocess=True)")
            return self._call_subprocess(tile, host, fidelity)
        try:
            value, meta = self._wall(tile, fidelity=fidelity)
        except ValueError:
            raise  # point-hygiene errors must surface, not score -inf
        except Exception as e:  # an infeasible tile config = failed run
            return -math.inf, {"error": f"{type(e).__name__}: {e}"}
        return value, dict(meta, kernel=self.kernel)

    # -- subprocess harness (host knobs) -------------------------------------
    def _call_subprocess(self, tile: Dict, host: Dict,
                         fidelity: Optional[float]) -> Tuple[float, dict]:
        payload = {"kernel": self.kernel, "shape": self.shape, "point": tile,
                   "fidelity": fidelity, **self._harness}
        env = dict(os.environ)
        flags = []
        if "host_devices" in host:
            flags.append("--xla_force_host_platform_device_count="
                         f"{int(host['host_devices'])}")
        if host.get("xla_flags"):
            flags.append(str(host["xla_flags"]))
        if flags:
            env["XLA_FLAGS"] = " ".join(flags)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.tuning.kernel_objective",
             json.dumps(payload)],
            capture_output=True, text=True, env=env, timeout=self.timeout)
        if proc.returncode != 0:
            return -math.inf, {"error": proc.stderr.strip()[-2000:],
                               "kernel": self.kernel, "host": host}
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        return float(out["value"]), dict(out["meta"], host=host)


def main(argv=None) -> int:
    """Subprocess entry: measure one payload, print one JSON line.

    ``python -m repro.tuning.kernel_objective '<payload json>'`` where
    payload = {kernel, shape, point, fidelity, warmup, iters, adaptive,
    rel_halfwidth}.  XLA_FLAGS/host knobs are the *caller's* job (set in
    this process's environment before jax is imported — which is why
    this module defers every jax import into the builders).
    """
    argv = sys.argv[1:] if argv is None else argv
    payload = json.loads(argv[0])
    ev = KernelTuneEvaluator(
        payload["kernel"], payload.get("shape"),
        warmup=int(payload.get("warmup", 1)),
        iters=int(payload.get("iters", 3)),
        adaptive=bool(payload.get("adaptive", True)),
        rel_halfwidth=float(payload.get("rel_halfwidth", 0.2)),
    )
    value, meta = ev(payload.get("point") or {},
                     fidelity=payload.get("fidelity"))
    print(json.dumps({"value": value, "meta": meta}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
