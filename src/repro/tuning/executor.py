"""Parallel evaluation executor — the measurement side of ask/tell.

The executor owns the worker pool and the memoization of completed
measurements.  It speaks two protocols:

* **batch** — ``evaluate(points) -> [EvalResult]`` runs a whole batch
  and returns results in submission order (the legacy barrier loop and
  standalone drivers use this);
* **completion-driven** — ``submit(points) -> [PendingEval]`` dispatches
  work without waiting and ``next_completed(pendings)`` blocks until
  *any* one of them finishes, so a driver can ``tell`` results the
  moment they land and refill the freed worker instead of idling the
  pool at a per-batch barrier.  ``as_completed(pendings)`` is the
  generator convenience over the same mechanism.

Shared semantics across both protocols:

* **failure isolation** — an objective that raises scores ``-inf`` (the
  paper's failed-run semantics for OOM/compile crashes) and the pool
  survives;
* **per-evaluation timeout** — a configuration that exceeds ``timeout``
  seconds scores ``-inf`` with ``meta={"timeout": True}`` (the paper's
  failed-run semantics: this configuration is too slow to measure).  The
  stuck worker is abandoned, not joined, so other evaluations keep
  flowing.  The clock starts at dispatch; a task still queued when its
  wait expires is cancelled and measured inline instead of being falsely
  recorded as a failure (remote backend: re-dispatched to the fleet with
  a fresh deadline instead — the workers own the real objective there);
* **wall-clock deadline** — ``next_completed``/``evaluate`` accept an
  absolute ``deadline`` (how the tuner bounds in-flight work against its
  ``wall_clock_budget``).  A deadline expiry is a *budget artifact of
  this run*, not a property of the configuration, so unfinished
  evaluations are **abandoned** at the deadline: nothing is recorded and
  nothing is cached, and a later run measures them normally;
* **shared memo cache** — completed evaluations (including failures) are
  memoized by grid key.  Pass ``cache_path`` (or a :class:`MemoCache`
  built on a :class:`~repro.tuning.cache.CacheStore`) to back the memo
  with an on-disk JSON store with atomic writes and cross-process file
  locking: repeated runs, resumed runs, and multiple hosts sharing a
  filesystem then reuse every measurement instead of re-compiling it.
  Timeout results stay in the in-memory memo only — a ``-inf`` under one
  run's timeout setting must not permanently poison the cross-run store;

Backends:

* ``"serial"`` — in-process, zero pool overhead.  ``parallelism=1``
  without a timeout defaults to this and reproduces the pre-batching
  sequential trace bit-for-bit.  (With a timeout set, the default is a
  1-worker thread pool, since only a pool can bound a running
  evaluation; the serial backend merely flags overruns after the fact.)
* ``"thread"`` — default for ``parallelism>1``.  Objectives that release
  the GIL (XLA compile/execute, subprocess measurement harnesses, any
  native code) scale; closures and unpicklable objectives all work.
* ``"process"`` — true CPU parallelism for picklable objectives.
* ``"remote"`` — measurements farmed to ``launch/worker.py`` daemons on
  other hosts over the length-prefixed-JSON RPC protocol
  (``repro.tuning.remote``); pass ``workers=["host:port", ...]``.
  Effective ``parallelism`` is the fleet's total slot count, a worker
  death reinjects its in-flight tasks (never recorded as config
  failures), preempting a task a worker already started keeps the
  let-it-finish semantics of a started pool task, and results are
  cached *by the tuner process* — workers never need the shared
  filesystem the cache store lives on.

Multi-fidelity support (the successive-halving stack, see
``repro.tuning.fidelity``):

* ``submit(points, fidelity=f)`` dispatches *partial* measurements —
  the evaluator's ``fidelity`` protocol (``repro.tuning.objective``)
  decides what a fraction of a measurement means.  Evaluators that do
  not opt in are measured at full fidelity and say so in
  ``meta["fidelity"]``;
* the memo cache keys low-fidelity results by **(grid key, fidelity)**:
  a cheap noisy measurement must never be served where a full one was
  requested (or vice versa), while full-fidelity entries keep the
  historical key format so existing on-disk stores load unchanged;
* ``preempt(pending)`` is the scheduler's kill switch for dispatched
  work that has since been dominated.  ``future.cancel()`` decides the
  outcome: a still-queued task is cancelled cleanly (never measured,
  nothing recorded, nothing cached — a later run can still measure it),
  while a task whose worker already started runs to completion and its
  result is recorded normally (the measurement is paid for; wasting it
  would lose information).  Both outcomes leave exactly-once recording
  intact — nothing is lost, nothing is double-recorded.
"""
from __future__ import annotations

import json
import math
import threading
import time
from concurrent.futures import (
    FIRST_COMPLETED,
    ProcessPoolExecutor,
    ThreadPoolExecutor,
    wait,
)
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional, Sequence

if TYPE_CHECKING:  # annotation-only: a runtime import would pull in all of
    # repro.core (and with it jax) — and create an import cycle that
    # breaks whichever of executor/tuner is imported first.  Measurement
    # workers import this module for run_objective and must stay light.
    from repro.core.space import SearchSpace

from repro.tuning.cache import (
    CacheStore,
    NullCacheStore,
    _round_trip_violation,
    ensure_serializable,
    open_store,
)
from repro.tuning.objective import Evaluator, as_evaluator
from repro.tuning.remote import FleetOptions, RemoteWorkerPool

BACKENDS = ("serial", "thread", "process", "remote")


@dataclass
class EvalResult:
    point: Dict
    value: float
    cost_seconds: float = 0.0
    meta: dict = field(default_factory=dict)


def run_objective(objective: Evaluator, point: Dict,
                  fidelity: Optional[float] = None,
                  resume_state: Optional[dict] = None):
    """One isolated evaluation: ``(value, seconds, meta)``.

    Module-level so the process backend can pickle it.  A raising
    objective is a failed configuration, not a pool failure.

    ``fidelity=None`` (or 1.0) calls the objective exactly like the
    historical no-fidelity path — the golden sequential traces depend on
    this.  A lower fidelity is forwarded iff the evaluator declares
    ``supports_fidelity``; otherwise the measurement silently upgrades
    to full fidelity and ``meta["fidelity"]`` reports the upgrade.

    ``resume_state`` is the checkpoint-fork blob (a prior step's
    ``meta["fork_state"]``), forwarded iff the evaluator declares
    ``supports_fork``; an evaluator without fork support measures the
    point from scratch, which is correct, just colder.
    """
    full = fidelity is None or fidelity >= 1.0
    kwargs = {}
    if resume_state is not None and getattr(objective, "supports_fork", False):
        kwargs["resume_state"] = resume_state
    t0 = time.time()
    try:
        if full or not getattr(objective, "supports_fidelity", False):
            value, meta = objective(point, **kwargs)
            delivered = 1.0
        else:
            value, meta = objective(point, fidelity=float(fidelity), **kwargs)
            delivered = float(fidelity)
        value = float(value)
        meta = dict(meta)
        if not full:  # full-fidelity meta stays exactly as the evaluator
            meta.setdefault("fidelity", delivered)  # made it (golden traces)
    except Exception as e:
        value, meta = -math.inf, {"error": repr(e)}
        if not full:
            meta["fidelity"] = float(fidelity)
    seconds = time.time() - t0
    # an evaluator that knows its own measurement cost (a harness timing
    # just the compile, or a benchmark with simulated costs) declares it
    # as meta["cost_seconds"], overriding the wall-clock default; this is
    # the signal cost-aware acquisition trains its cost model on, so a
    # declared cost keeps it deterministic under harness noise
    declared = meta.get("cost_seconds")
    if isinstance(declared, (int, float)) and not isinstance(declared, bool) \
            and math.isfinite(declared) and declared >= 0:
        seconds = float(declared)
    return value, seconds, meta


def _canon_key_component(c):
    """Canonical JSON form of one grid-key component.

    Tuples become lists (so the fidelity marker stays parseable by
    ``MemoCache._stored_fidelity``) and numpy scalars unwrap via
    ``.item()`` — a *lossless* coercion (``np.int64(3)`` -> ``3``), so a
    space built from e.g. ``np.linspace`` values keys identically to its
    plain-Python spelling for both store and lookup.  Duck-typed on the
    type's module so measurement workers importing this module never pay
    a numpy import.  Anything else passes through for the strict
    round-trip check to judge.
    """
    if isinstance(c, (tuple, list)):
        return [_canon_key_component(v) for v in c]
    if type(c).__module__ == "numpy" and getattr(c, "ndim", 1) == 0:
        v = c.item()
        # .item() can hand back the same numpy type when there is no
        # lossless Python equivalent (np.longdouble): leave it for the
        # round-trip check to reject instead of recursing forever
        if type(v) is not type(c):
            return _canon_key_component(v)
    return c


def _store_key(key) -> str:
    """Stable string form of a grid key for the on-disk store.

    Components are canonicalized first (:func:`_canon_key_component`:
    tuples -> lists, numpy scalars -> their exact Python values) and
    serialization is then **strict**: a component that is still not
    canonical JSON — an arbitrary object, a lossy exotic scalar — raises
    ``TypeError`` naming it.  The historical ``default=str`` fallback
    silently stringified such components, producing store keys that
    could collide with (or never round-trip back to) the honest
    spelling.
    """
    parts = [_canon_key_component(c) for c in key]
    bad = _round_trip_violation(parts, path="grid key")
    if bad:
        raise TypeError(
            f"grid key {tuple(key)!r} is not strictly JSON-serializable: "
            f"{bad}; refusing to persist under a default=str spelling")
    return json.dumps(parts)


_FID_TAG = "__fidelity__"


def memo_key(grid_key, fidelity: Optional[float]) -> tuple:
    """Memo identity of a measurement: the grid key, plus the fidelity
    when (and only when) it is partial.

    Full-fidelity keys are exactly the historical grid keys, so existing
    in-memory memos and on-disk stores keep working unchanged; partial
    measurements get a distinct key so a cheap noisy result is never
    served where a full measurement was requested."""
    grid_key = tuple(grid_key)
    if fidelity is None or fidelity >= 1.0:
        return grid_key
    return grid_key + ((_FID_TAG, round(float(fidelity), 9)),)


_LIN_TAG = "__lineage__"


def lineage_key(key, lineage: Optional[str], rung: Optional[int]) -> tuple:
    """Isolate a *stateful* measurement's memo identity by its lineage
    and step.

    A checkpoint-forked step is not a pure function of (point, fidelity)
    — it also depends on the opaque ``resume_state`` it continued from —
    so two lineages (or two steps of one lineage) at the same point must
    never share a memo hit.  Stateless measurements keep the plain
    (point, fidelity) key and keep sharing, which is why this tag is
    applied only when a state blob rides the submission."""
    return tuple(key) + ((_LIN_TAG, str(lineage or ""), int(rung or 0)),)


def grid_key_of(key) -> tuple:
    """Strip the fidelity/lineage markers (if any) off a memo key."""
    key = tuple(key)
    while key and isinstance(key[-1], tuple) and key[-1] \
            and key[-1][0] in (_FID_TAG, _LIN_TAG):
        key = key[:-1]
    return key


class MemoCache:
    """Shared memo of completed evaluations, keyed by ``space.key(point)``.

    Optionally write-through to a :class:`~repro.tuning.cache.CacheStore`
    so entries persist across processes, runs, and hosts.  Records are
    stored as ``{"point", "value", "cost_seconds", "meta"}`` so a
    different process can re-derive the grid key from the point under
    its own ``SearchSpace``.

    Persistence granularity: with ``autoflush=True`` (the default, and
    the historical behavior) every ``put`` is its own store write.  The
    executor constructs its caches with ``autoflush=False`` and calls
    :meth:`flush` once per completion drain instead, so N completions
    cost one read-merge-write of the store file rather than N — records
    are still *validated* serializable at ``put`` time (the error must
    name the evaluation that produced it, not surface at some later
    flush).  ``flushes`` counts actual store writes for tests and
    observability.
    """

    def __init__(self, backing=None, lock=None,
                 store: Optional[CacheStore] = None, autoflush: bool = True):
        self._d = {} if backing is None else backing
        self._lock = lock if lock is not None else threading.Lock()
        self._store = store if store is not None else open_store(None)
        self._persistent = not isinstance(self._store, NullCacheStore)
        self._autoflush = autoflush
        self._dirty: Dict[str, dict] = {}
        self.flushes = 0

    @classmethod
    def process_safe(cls, store: Optional[CacheStore] = None,
                     autoflush: bool = True) -> "MemoCache":
        import multiprocessing

        manager = multiprocessing.Manager()
        return cls(backing=manager.dict(), lock=manager.Lock(), store=store,
                   autoflush=autoflush)

    @staticmethod
    def _stored_fidelity(store_key: str) -> Optional[float]:
        """Requested fidelity embedded in a persisted key, or None.

        The *requested* fidelity is the lookup identity (an evaluator may
        deliver a snapped/clamped fidelity in meta, which would never
        match a repeat request), and it is space-independent, so parsing
        it off the stored key keeps the re-derive-grid-key-from-point
        behavior for the rest of the key.
        """
        try:
            parsed = json.loads(store_key)
        except (json.JSONDecodeError, TypeError):
            return None
        if (isinstance(parsed, list) and parsed
                and isinstance(parsed[-1], list) and parsed[-1]
                and parsed[-1][0] == _FID_TAG):
            return float(parsed[-1][1])
        return None

    def load_store(self, space: SearchSpace) -> int:
        """Seed the in-memory memo from the persistent store; return count."""
        n = 0
        for skey, rec in self._store.load().items():
            key = memo_key(space.key(rec["point"]),
                           self._stored_fidelity(skey))
            with self._lock:
                if key not in self._d:
                    self._d[key] = EvalResult(
                        dict(rec["point"]), float(rec["value"]),
                        float(rec.get("cost_seconds", 0.0)),
                        dict(rec.get("meta") or {}))
                    n += 1
        return n

    def get(self, key) -> Optional[EvalResult]:
        with self._lock:
            return self._d.get(key)

    def put(self, key, result: EvalResult, persist: bool = True) -> None:
        with self._lock:
            self._d[key] = result
        if not (persist and self._persistent):
            return
        skey = _store_key(key)
        record = {
            "point": result.point, "value": result.value,
            "cost_seconds": result.cost_seconds, "meta": result.meta,
        }
        if self._autoflush:
            self._store.put(skey, record)  # put_many validates
            self.flushes += 1
        else:
            # fail at put time, not at some later flush: the traceback
            # must point at the evaluation whose record is broken
            ensure_serializable(skey, record)
            with self._lock:
                self._dirty[skey] = record

    def flush(self) -> None:
        """Persist buffered puts as one store write (no-op when clean)."""
        with self._lock:
            dirty, self._dirty = self._dirty, {}
        if dirty:
            self._store.put_many(dirty)
            self.flushes += 1

    def __len__(self) -> int:
        return len(self._d)


class PendingEval:
    """A dispatched evaluation: completed (``done()``) or still running.

    ``deadline`` is the absolute time by which the evaluation must have
    produced a result; past it, ``next_completed`` resolves the pending
    to ``-inf`` with ``meta={"timeout": True}`` (or measures it inline
    if the pool never actually started it).

    ``fidelity``/``rung`` tag partial measurements for the trial
    scheduler (``None`` = full measurement, outside any scheduler);
    ``state``/``lineage`` tag checkpoint-fork steps (PBT): ``state`` is
    the opaque ``resume_state`` blob forwarded to the evaluator and
    ``lineage`` the trial ancestry recorded in History.  ``preempted``
    records that the scheduler asked for this evaluation to be killed —
    whether the kill landed is ``preempt``'s return value, not this
    flag.
    """

    __slots__ = ("point", "key", "index", "submitted_at", "deadline",
                 "future", "fidelity", "rung", "state", "lineage",
                 "preempted", "_result")

    def __init__(self, point, key, index, future=None, result=None,
                 deadline=None, fidelity=None, rung=None, state=None,
                 lineage=None):
        self.point = point
        self.key = key
        self.index = index
        self.submitted_at = time.time()
        self.deadline = deadline
        self.future = future
        self.fidelity = fidelity
        self.rung = rung
        self.state = state
        self.lineage = lineage
        self.preempted = False
        self._result = result

    def done(self) -> bool:
        return self._result is not None

    def result(self) -> EvalResult:
        assert self._result is not None, "pending evaluation not complete"
        return self._result


class EvaluationExecutor:
    def __init__(
        self,
        objective,
        space: SearchSpace,
        *,
        parallelism: int = 1,
        backend: Optional[str] = None,
        timeout: Optional[float] = None,
        cache: Optional[MemoCache] = None,
        cache_path: Optional[str] = None,
        workers: Optional[Sequence[str]] = None,
        pool=None,
        corpus=None,
        fleet: Optional[FleetOptions] = None,
    ):
        self.objective = as_evaluator(objective)
        self.space = space
        self._parallelism = max(1, int(parallelism))
        #: fair-share throttle: when set (the tuning service's slot
        #: governor), ``parallelism`` reports at most this many slots,
        #: so a multi-tenant driver keeps its in-flight window inside
        #: its share of a shared pool.  Dispatched work is never
        #: revoked by lowering it — the window shrinks as results land.
        self.slot_cap: Optional[int] = None
        # a shared pool (multi-tenant service: N executors over one
        # worker fleet / thread pool) is injected pre-built; this
        # executor then never shuts it down
        self._owns_pool = pool is None
        # a timeout needs a pool to enforce it mid-run: the serial backend
        # can only flag an overrun after the objective returns
        if backend is None:
            if pool is not None:
                backend = ("remote" if isinstance(pool, RemoteWorkerPool)
                           else "thread")
            elif workers:
                backend = "remote"
            else:
                backend = ("serial"
                           if self._parallelism == 1 and timeout is None
                           else "thread")
        self.backend = backend
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown executor backend {self.backend!r}; one of {BACKENDS}")
        self.fleet = fleet
        elastic = fleet is not None and fleet.listen_port is not None
        if (self.backend == "remote" and not workers and pool is None
                and not elastic):
            raise ValueError(
                "backend='remote' needs workers=['host:port', ...] "
                "(launch/worker.py daemons), a shared pool=, or fleet= "
                "with a join socket for workers to dial in")
        if workers and self.backend != "remote":
            raise ValueError(
                f"workers= is only meaningful with backend='remote' "
                f"(got backend={self.backend!r})")
        self.workers = list(workers) if workers else None
        self.timeout = timeout
        if cache is not None and cache_path is not None:
            raise ValueError(
                "pass either cache= (a shared MemoCache, which carries its "
                "own store) or cache_path=, not both — cache_path would be "
                "silently ignored")
        store = open_store(cache_path) if cache_path else None
        if cache is not None:
            self.cache = cache
        elif self.backend == "process":
            self.cache = MemoCache.process_safe(store=store, autoflush=False)
        else:
            self.cache = MemoCache(store=store, autoflush=False)
        if store is not None:
            self.cache.load_store(space)
        #: optional cross-job observation corpus (transfer learning,
        #: ``repro.tuning.corpus``): every finalized real measurement is
        #: appended under this job's workload descriptor and flushed with
        #: the memo cache
        self.corpus = corpus
        if corpus is not None and corpus.descriptor is None:
            corpus.describe_job(self.objective, space)
        self._pool = pool
        self._inflight: Dict = {}  # grid key -> future currently measuring it
        self._seq = 0  # monotonic submission index (orders completions)
        if self.backend == "remote" and self._pool is None:
            # connect eagerly: fail fast on an unreachable fleet, and the
            # drivers size their in-flight window off the fleet's actual
            # capacity (registered worker slots), not a local guess
            self._pool = RemoteWorkerPool(self.workers or [],
                                          eval_timeout=self.timeout,
                                          fleet=self.fleet)

    @property
    def remote_pool(self) -> Optional[RemoteWorkerPool]:
        """The live fleet (remote backend only) — drivers use it to print
        the join address and to render speculation / straggler status."""
        return self._pool if self.backend == "remote" else None

    @property
    def parallelism(self) -> int:
        """Measurement capacity the driver should keep in flight.  For
        the remote backend this is the *live* fleet's slot total — it
        shrinks when a worker dies, so the driver stops overfilling the
        queue and starving tasks into their per-eval deadlines.  A
        ``slot_cap`` (fair-share governor) caps either backend."""
        if self.backend == "remote" and self._pool is not None:
            base = max(1, self._pool.parallelism)
        else:
            base = self._parallelism
        if self.slot_cap is not None:
            base = max(1, min(base, int(self.slot_cap)))
        return base

    def _get_pool(self):
        if self._pool is None:
            if self.backend == "thread":
                self._pool = ThreadPoolExecutor(max_workers=self.parallelism)
            elif self.backend == "process":
                self._pool = ProcessPoolExecutor(max_workers=self.parallelism)
        return self._pool

    def _corpus_add(self, result: EvalResult,
                    fidelity: Optional[float] = None) -> None:
        """Append one finalized real measurement to the transfer corpus.

        Memoized aliases, preempted placeholders, and timeout verdicts
        are not measurements of this workload (same judgment calls as
        memo persistence) and are skipped; failed configurations
        (``-inf``) are recorded — "this config crashes here" transfers.
        """
        if self.corpus is None:
            return
        m = result.meta
        if m.get("memoized") or m.get("preempted") or m.get("timeout"):
            return
        fid = m.get("fidelity")
        if fid is None:
            fid = 1.0 if fidelity is None else float(fidelity)
        self.corpus.add(result.point, result.value, result.cost_seconds,
                        float(fid))

    def _flush(self) -> None:
        """One store write for the memo cache and the corpus alike."""
        self.cache.flush()
        if self.corpus is not None:
            self.corpus.flush()

    # -- completion-driven protocol ------------------------------------------
    def submit(self, points: Sequence[Dict],
               fidelity: Optional[float] = None,
               rung: Optional[int] = None,
               state: Optional[dict] = None,
               lineage: Optional[str] = None) -> List[PendingEval]:
        """Dispatch evaluations without waiting; returns one pending each.

        Memo-cache hits come back already completed (zero cost,
        ``meta["memoized"]``).  Duplicate keys already in flight share
        the running measurement instead of re-dispatching it.  Each
        dispatched pending carries a per-evaluation deadline of
        ``now + timeout`` (when a timeout is set); wall-clock budgeting
        is the *caller's* deadline, passed to ``next_completed``.

        ``fidelity`` requests partial measurements (evaluator fidelity
        protocol); partial results are memoized under (grid key,
        fidelity) so they are only ever reused at the same fidelity.
        ``rung`` is an opaque tag echoed on the pendings for the trial
        scheduler's bookkeeping.

        ``state`` is an opaque checkpoint-fork blob forwarded to the
        evaluator as ``resume_state`` (PBT): a stateful submission is
        not a pure function of (point, fidelity), so its memo key is
        additionally tagged with (``lineage``, ``rung``) — forked
        lineages never collide with each other or with stateless
        measurements of the same point — and its result is memoized
        in-process only: never persisted to the cross-run store, never
        fed to the transfer corpus.
        """
        # an objective that cannot vary fidelity always delivers a full
        # measurement: key (and run) it as one, or identical full results
        # would fragment across per-fidelity memo keys and re-measure
        if fidelity is not None \
                and not getattr(self.objective, "supports_fidelity", False):
            fidelity = None
        out: List[PendingEval] = []
        for p in points:
            key = memo_key(self.space.key(p), fidelity)
            if state is not None:
                key = lineage_key(key, lineage, rung)
            self._seq += 1
            hit = self.cache.get(key)
            if hit is not None:
                out.append(PendingEval(
                    dict(p), key, self._seq, fidelity=fidelity, rung=rung,
                    state=state, lineage=lineage,
                    result=EvalResult(dict(p), hit.value, 0.0,
                                      dict(hit.meta, memoized=True))))
                continue
            eval_deadline = (time.time() + self.timeout
                             if self.timeout is not None else None)
            stale = self._inflight.get(key)
            if stale is not None and stale.cancelled():
                # preempted before it ever started: nothing was measured,
                # so dispatch a fresh measurement instead of aliasing
                del self._inflight[key]
                stale = None
            if stale is not None and stale.done():
                # a previously abandoned measurement finished after its
                # driver moved on: harvest it into the cache now
                self._harvest(key, stale)
                hit = self.cache.get(key)
                out.append(PendingEval(
                    dict(p), key, self._seq, fidelity=fidelity, rung=rung,
                    state=state, lineage=lineage,
                    result=EvalResult(dict(p), hit.value, 0.0,
                                      dict(hit.meta, memoized=True))))
                continue
            if stale is not None:
                out.append(PendingEval(dict(p), key, self._seq, future=stale,
                                       deadline=eval_deadline,
                                       fidelity=fidelity, rung=rung,
                                       state=state, lineage=lineage))
                continue
            if self.backend == "serial":
                out.append(PendingEval(dict(p), key, self._seq,
                                       fidelity=fidelity, rung=rung,
                                       state=state, lineage=lineage,
                                       result=self._run_one(p, fidelity,
                                                            state)))
                r = out[-1].result()
                self.cache.put(key, r, persist=state is None
                               and not r.meta.get("timeout"))
                if state is None:
                    self._corpus_add(r, fidelity)
                continue
            fut = self._submit_to_pool(p, fidelity, state)
            self._inflight[key] = fut
            out.append(PendingEval(dict(p), key, self._seq, future=fut,
                                   deadline=eval_deadline,
                                   fidelity=fidelity, rung=rung,
                                   state=state, lineage=lineage))
        self._flush()  # serial-path results + harvested strays
        return out

    def _submit_to_pool(self, point: Dict, fidelity: Optional[float],
                        state: Optional[dict]):
        """Dispatch one measurement to the pool backend.

        The stateless spelling is kept positionally identical to the
        historical call so thread/process/remote pools and their tests
        see the exact same submission; the ``resume_state`` argument is
        appended only when a checkpoint-fork blob actually rides along.
        """
        if state is None:
            return self._get_pool().submit(run_objective, self.objective,
                                           point, fidelity)
        return self._get_pool().submit(run_objective, self.objective,
                                       point, fidelity, state)

    @staticmethod
    def _stateful_key(key) -> bool:
        return bool(key) and isinstance(key[-1], tuple) and key[-1] \
            and key[-1][0] == _LIN_TAG

    def _harvest(self, key, future) -> None:
        """Bank an abandoned-but-finished measurement into the memo."""
        value, secs, meta = future.result()
        if self._inflight.get(key) is future:
            del self._inflight[key]
        point = dict(zip(self.space.names, grid_key_of(key)))
        res = EvalResult(point, value, secs, meta)
        if self._stateful_key(key):
            # a checkpoint-fork step: valid only within its lineage —
            # memoize in-process, never persist or feed the corpus
            self.cache.put(key, res, persist=False)
            return
        self.cache.put(key, res)
        self._corpus_add(res)  # a paid-for real measurement, late or not

    def _finalize(self, pending: PendingEval) -> None:
        """Turn a completed future into the pending's EvalResult + memo."""
        if pending.future.cancelled():
            # a sibling pending sharing this measurement was preempted
            # before the worker started: nothing was measured, so this
            # alias resolves to the same not-recorded placeholder (a later
            # submit measures the point for real)
            if self._inflight.get(pending.key) is pending.future:
                del self._inflight[pending.key]
            pending.preempted = True
            pending._result = EvalResult(dict(pending.point), -math.inf,
                                         0.0, {"preempted": True})
            return
        value, secs, meta = pending.future.result()
        if self._inflight.get(pending.key) is pending.future:
            del self._inflight[pending.key]
            pending._result = EvalResult(dict(pending.point), value, secs,
                                         meta)
            self.cache.put(pending.key, pending._result,
                           persist=pending.state is None)
            if pending.state is None:
                self._corpus_add(pending._result, pending.fidelity)
        else:
            # an alias of a measurement another pending already finalized:
            # like every memoized path, it costs 0.0 — charging the full
            # measurement twice would inflate cost accounting downstream
            pending._result = EvalResult(dict(pending.point), value, 0.0,
                                         dict(meta, memoized=True))

    def preempt(self, pending: PendingEval) -> str:
        """Best-effort kill of a dispatched evaluation the caller no longer
        wants (a successive-halving rung outclassed it while in flight).

        Returns one of:

        * ``"cancelled"`` — the task had not started; it is resolved to a
          ``meta={"preempted": True}`` placeholder that is **not** cached
          and must not be recorded (the point was never measured; a later
          submit measures it normally);
        * ``"running"`` — a worker already started (``future.cancel()``
          returned False): the measurement runs to completion and its
          result arrives through ``next_completed`` exactly as usual —
          it was paid for, so the caller records it normally;
        * ``"done"`` — the result already exists; the caller must record
          it (preempting a completed evaluation is a no-op).

        Every path keeps exactly-once accounting: a pending is either
        resolved to a preempted placeholder (never recorded, never
        cached) or produces exactly one real result.
        """
        if pending.done():
            return "done"
        if pending.future is None:  # serial backend resolves at submit
            return "done"
        pending.preempted = True
        if pending.future.cancel():
            if self._inflight.get(pending.key) is pending.future:
                del self._inflight[pending.key]
            pending._result = EvalResult(
                dict(pending.point), -math.inf, 0.0, {"preempted": True})
            return "cancelled"
        # the worker beat us to it (or another pending shares the future):
        # let the measurement finish and be recorded — killing a running
        # thread is impossible and wasting a paid-for result loses data
        return "running"

    def _resolve_timeout(self, pending: PendingEval, now: float) -> bool:
        """Per-evaluation timeout expiry (never wall-clock expiry).
        Returns False when the pending was *re-dispatched* instead of
        resolved (remote backend, see below) — the caller keeps waiting.
        """
        if self._inflight.get(pending.key) is pending.future:
            del self._inflight[pending.key]
        if pending.future.cancel():
            # never started (pool starved by earlier slow evals): this point
            # was not measured at all — recording a bogus failure is wrong
            if self.backend == "remote":
                # ...and so is measuring it inline: the tuner-side
                # objective is a stand-in over this backend (workers own
                # the real one).  Re-dispatch to the fleet with a fresh
                # deadline — the timeout clock properly starts at
                # dispatch, and this task never was dispatched.
                fut = self._submit_to_pool(pending.point, pending.fidelity,
                                           pending.state)
                self._inflight[pending.key] = fut
                pending.future = fut
                pending.submitted_at = now
                pending.deadline = (now + self.timeout
                                    if self.timeout is not None else None)
                return False
            pending._result = self._run_one(pending.point, pending.fidelity,
                                            pending.state)
        else:
            # genuinely running too long: abandon the stuck worker (it is
            # not joined); the pool survives
            secs = (float(self.timeout) if self.timeout is not None
                    else now - pending.submitted_at)
            pending._result = EvalResult(dict(pending.point), -math.inf,
                                         secs, {"timeout": True})
        # memoize within this run, but never persist a timeout verdict to
        # the cross-run store: it reflects this run's timeout setting, not
        # the configuration itself (stateful fork steps never persist)
        self.cache.put(pending.key, pending._result,
                       persist=pending.state is None
                       and not pending._result.meta.get("timeout"))
        # the inline-measurement branch is a real measurement; the helper
        # skips the timeout verdicts itself
        if pending.state is None:
            self._corpus_add(pending._result, pending.fidelity)
        return True

    def next_completed(self, pendings: Sequence[PendingEval],
                       deadline: Optional[float] = None,
                       ) -> Optional[PendingEval]:
        """Block until any pending completes; return it (submission-order
        tie-break when several are ready).  Returns ``None`` only when
        ``deadline`` passes with nothing resolvable — timed-out
        evaluations resolve to ``-inf`` results, not to ``None``."""
        pendings = sorted(pendings, key=lambda p: p.index)
        while True:
            for p in pendings:
                if p.done():
                    return p
            if not pendings:
                return None
            now = time.time()
            waits = [p.deadline - now for p in pendings
                     if p.deadline is not None]
            if deadline is not None:
                waits.append(deadline - now)
            wait_s = max(0.0, min(waits)) if waits else None
            done, _ = wait({p.future for p in pendings}, timeout=wait_s,
                           return_when=FIRST_COMPLETED)
            if done:
                # drain everything that is ready, then persist the whole
                # drain as ONE store flush: N simultaneous completions
                # cost one read-merge-write of the cache file, not N
                # (the stragglers return instantly from done() on the
                # caller's next call, without touching the store)
                first = None
                for p in pendings:
                    if p.future in done:
                        self._finalize(p)
                        if first is None:
                            first = p
                self._flush()
                return first
            now = time.time()
            for p in pendings:
                if p.deadline is not None and now >= p.deadline:
                    if self._resolve_timeout(p, now):
                        self._flush()
                        return p
                    # re-dispatched (remote starvation): keep waiting
            if deadline is not None and now >= deadline:
                return None

    def as_completed(self, pendings: Sequence[PendingEval],
                     deadline: Optional[float] = None,
                     ) -> Iterator[PendingEval]:
        """Yield pendings as they complete (completion order)."""
        remaining = list(pendings)
        while remaining:
            p = self.next_completed(remaining, deadline=deadline)
            if p is None:
                return
            remaining.remove(p)
            yield p

    # -- batch protocol ------------------------------------------------------
    def evaluate(self, points: List[Dict],
                 deadline: Optional[float] = None) -> List[Optional[EvalResult]]:
        """Evaluate a batch; results in submission order.

        With a ``deadline``, evaluations not finished when it passes are
        *abandoned*: their slot in the returned list is ``None`` (not a
        fake ``-inf``), nothing is cached, and a later run measures them
        normally.  Per-evaluation ``timeout`` expiries still resolve to
        ``-inf`` timeout results as always.
        """
        results: List[Optional[EvalResult]] = [None] * len(points)
        abandoned = [False] * len(points)
        todo: List[int] = []  # indices that miss the memo cache
        first_at: Dict = {}  # key -> index of first in-batch occurrence
        for i, p in enumerate(points):
            key = self.space.key(p)
            hit = self.cache.get(key)
            if hit is not None:
                results[i] = EvalResult(dict(p), hit.value, 0.0,
                                        dict(hit.meta, memoized=True))
            elif key in first_at:
                pass  # in-batch duplicate: aliased after the batch runs
            else:
                first_at[key] = i
                todo.append(i)

        if todo:
            if self.backend == "serial":
                for i in todo:
                    if deadline is not None and time.time() >= deadline:
                        abandoned[i] = True  # budget spent: don't even start
                        continue
                    results[i] = self._run_one(points[i])
            else:
                pool = self._get_pool()
                futures = [(i, pool.submit(run_objective, self.objective,
                                           points[i]))
                           for i in todo]
                dispatched_at = time.time()
                for i, fut in futures:
                    wait_s = self.timeout
                    if deadline is not None:
                        left = max(0.0, deadline - time.time())
                        wait_s = left if wait_s is None else min(wait_s, left)
                    try:
                        value, secs, meta = fut.result(timeout=wait_s)
                    except FutureTimeoutError:
                        timed_out = (self.timeout is not None and
                                     time.time() - dispatched_at
                                     >= self.timeout)
                        if not timed_out:
                            # pure wall-clock expiry: a budget artifact of
                            # this run, not a failed configuration — abandon
                            # (queued tasks are cancelled, running workers
                            # left to finish unrecorded)
                            fut.cancel()
                            abandoned[i] = True
                            continue
                        if fut.cancel():
                            if (deadline is not None
                                    and time.time() >= deadline):
                                # starved AND out of budget: abandoning beats
                                # an inline measurement that would overshoot
                                # the wall clock unboundedly
                                abandoned[i] = True
                                continue
                            # never started (pool starved by earlier slow
                            # evals): this point was not measured at all, so
                            # give it its run rather than recording a bogus
                            # failure
                            if self.backend == "remote":
                                # ...but not inline: the tuner-side
                                # objective is a stand-in over this backend
                                # (mirrors _resolve_timeout).  One fresh
                                # dispatch to the fleet; if that starves or
                                # busts the budget too, abandon unrecorded.
                                retry = pool.submit(run_objective,
                                                    self.objective, points[i])
                                retry_s = self.timeout
                                if deadline is not None:
                                    left = max(0.0, deadline - time.time())
                                    retry_s = (left if retry_s is None
                                               else min(retry_s, left))
                                try:
                                    value, secs, meta = retry.result(
                                        timeout=retry_s)
                                except FutureTimeoutError:
                                    if retry.cancel() or (
                                            deadline is not None
                                            and time.time() >= deadline):
                                        abandoned[i] = True
                                        continue
                                    value, secs, meta = (
                                        -math.inf, float(self.timeout),
                                        {"timeout": True})
                                results[i] = EvalResult(dict(points[i]),
                                                        value, secs, meta)
                                continue
                            results[i] = self._run_one(points[i])
                            continue
                        # genuinely running too long: abandon the stuck
                        # worker (it is not joined); the pool survives
                        value, secs, meta = (-math.inf, float(self.timeout),
                                             {"timeout": True})
                    results[i] = EvalResult(dict(points[i]), value, secs, meta)
            for i in todo:
                if results[i] is not None:
                    self.cache.put(self.space.key(points[i]), results[i],
                                   persist=not results[i].meta.get("timeout"))
                    self._corpus_add(results[i])
            self._flush()  # the whole batch is one store write

        for i, p in enumerate(points):  # resolve in-batch duplicates
            if results[i] is None and not abandoned[i]:
                src = results[first_at[self.space.key(p)]]
                if src is None:
                    continue  # its source was abandoned at the deadline
                results[i] = EvalResult(dict(p), src.value, 0.0,
                                        dict(src.meta, memoized=True))
        return results

    def _run_one(self, point: Dict,
                 fidelity: Optional[float] = None,
                 state: Optional[dict] = None) -> EvalResult:
        value, secs, meta = run_objective(self.objective, point, fidelity,
                                          state)
        if self.timeout is not None and secs > self.timeout:
            value, meta = -math.inf, dict(meta, timeout=True)
        return EvalResult(dict(point), value, secs, meta)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        self._flush()  # nothing buffered may outlive the executor
        if self._pool is not None:
            if self._owns_pool:  # a shared pool outlives its tenants
                self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
        self._inflight.clear()

    def __enter__(self) -> "EvaluationExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
