"""Parallel evaluation executor — the measurement side of ask/tell.

The tuner asks an engine for a batch of candidate points and hands the
batch here.  The executor runs the objective over a worker pool with:

* **failure isolation** — an objective that raises scores ``-inf`` (the
  paper's failed-run semantics for OOM/compile crashes) and the pool
  survives;
* **per-evaluation timeout** — a configuration that exceeds ``timeout``
  seconds scores ``-inf`` with ``meta={"timeout": True}``.  The stuck
  worker is abandoned, not joined, so the batch still completes.  The
  clock starts at batch dispatch; a task still queued when its wait
  expires is cancelled and measured inline instead of being falsely
  recorded as a failure;
* **shared memo cache** — completed evaluations (including failures and
  timeouts) are memoized by grid key, so repeated queries across batches
  are free when the executor is used standalone or shared between
  drivers.  (Inside a :class:`~repro.core.tuner.Tuner`, the history
  already memoizes repeats before they reach the executor; this cache is
  the executor's own guarantee, not the tuner's.)  With the process
  backend it is backed by a ``multiprocessing.Manager`` dict, making it
  safe to share across processes;
* **deterministic ordering** — results come back in submission order
  regardless of completion order, so engine ``tell`` and the history
  stay reproducible.

Backends:

* ``"serial"`` — in-process, zero pool overhead.  ``parallelism=1``
  without a timeout defaults to this and reproduces the pre-batching
  sequential trace bit-for-bit.  (With a timeout set, the default is a
  1-worker thread pool, since only a pool can bound a running
  evaluation; the serial backend merely flags overruns after the fact.)
* ``"thread"`` — default for ``parallelism>1``.  Objectives that release
  the GIL (XLA compile/execute, subprocess measurement harnesses, any
  native code) scale; closures and unpicklable objectives all work.
* ``"process"`` — true CPU parallelism for picklable objectives.
"""
from __future__ import annotations

import math
import threading
import time
from concurrent.futures import (
    ProcessPoolExecutor,
    ThreadPoolExecutor,
)
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.space import SearchSpace
from repro.tuning.objective import Evaluator, as_evaluator

BACKENDS = ("serial", "thread", "process")


@dataclass
class EvalResult:
    point: Dict
    value: float
    cost_seconds: float = 0.0
    meta: dict = field(default_factory=dict)


def run_objective(objective: Evaluator, point: Dict):
    """One isolated evaluation: ``(value, seconds, meta)``.

    Module-level so the process backend can pickle it.  A raising
    objective is a failed configuration, not a pool failure.
    """
    t0 = time.time()
    try:
        value, meta = objective(point)
        value = float(value)
        meta = dict(meta)
    except Exception as e:
        value, meta = -math.inf, {"error": repr(e)}
    return value, time.time() - t0, meta


class MemoCache:
    """Shared memo of completed evaluations, keyed by ``space.key(point)``."""

    def __init__(self, backing=None, lock=None):
        self._d = {} if backing is None else backing
        self._lock = lock if lock is not None else threading.Lock()

    @classmethod
    def process_safe(cls) -> "MemoCache":
        import multiprocessing

        manager = multiprocessing.Manager()
        return cls(backing=manager.dict(), lock=manager.Lock())

    def get(self, key) -> Optional[EvalResult]:
        with self._lock:
            return self._d.get(key)

    def put(self, key, result: EvalResult) -> None:
        with self._lock:
            self._d[key] = result

    def __len__(self) -> int:
        return len(self._d)


class EvaluationExecutor:
    def __init__(
        self,
        objective,
        space: SearchSpace,
        *,
        parallelism: int = 1,
        backend: Optional[str] = None,
        timeout: Optional[float] = None,
        cache: Optional[MemoCache] = None,
    ):
        self.objective = as_evaluator(objective)
        self.space = space
        self.parallelism = max(1, int(parallelism))
        # a timeout needs a pool to enforce it mid-run: the serial backend
        # can only flag an overrun after the objective returns
        if backend is None:
            backend = ("serial" if self.parallelism == 1 and timeout is None
                       else "thread")
        self.backend = backend
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown executor backend {self.backend!r}; one of {BACKENDS}")
        self.timeout = timeout
        if cache is not None:
            self.cache = cache
        elif self.backend == "process":
            self.cache = MemoCache.process_safe()
        else:
            self.cache = MemoCache()
        self._pool = None

    def _get_pool(self):
        if self._pool is None:
            if self.backend == "thread":
                self._pool = ThreadPoolExecutor(max_workers=self.parallelism)
            elif self.backend == "process":
                self._pool = ProcessPoolExecutor(max_workers=self.parallelism)
        return self._pool

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, points: List[Dict]) -> List[EvalResult]:
        """Evaluate a batch; results in submission order."""
        results: List[Optional[EvalResult]] = [None] * len(points)
        todo: List[int] = []  # indices that miss the memo cache
        first_at: Dict = {}  # key -> index of first in-batch occurrence
        for i, p in enumerate(points):
            key = self.space.key(p)
            hit = self.cache.get(key)
            if hit is not None:
                results[i] = EvalResult(dict(p), hit.value, 0.0,
                                        dict(hit.meta, memoized=True))
            elif key in first_at:
                pass  # in-batch duplicate: aliased after the batch runs
            else:
                first_at[key] = i
                todo.append(i)

        if todo:
            if self.backend == "serial":
                for i in todo:
                    results[i] = self._run_one(points[i])
            else:
                pool = self._get_pool()
                futures = [(i, pool.submit(run_objective, self.objective,
                                           points[i]))
                           for i in todo]
                for i, fut in futures:
                    try:
                        value, secs, meta = fut.result(timeout=self.timeout)
                    except FutureTimeoutError:
                        if fut.cancel():
                            # never started (pool starved by earlier slow
                            # evals): this point was not measured at all, so
                            # give it its run inline rather than recording a
                            # bogus failure
                            results[i] = self._run_one(points[i])
                            continue
                        # genuinely running too long: abandon the stuck
                        # worker (it is not joined); the pool survives
                        value, secs, meta = (-math.inf, float(self.timeout),
                                             {"timeout": True})
                    results[i] = EvalResult(dict(points[i]), value, secs, meta)
            for i in todo:
                self.cache.put(self.space.key(points[i]), results[i])

        for i, p in enumerate(points):  # resolve in-batch duplicates
            if results[i] is None:
                src = results[first_at[self.space.key(p)]]
                results[i] = EvalResult(dict(p), src.value, 0.0,
                                        dict(src.meta, memoized=True))
        return results

    def _run_one(self, point: Dict) -> EvalResult:
        value, secs, meta = run_objective(self.objective, point)
        if self.timeout is not None and secs > self.timeout:
            value, meta = -math.inf, dict(meta, timeout=True)
        return EvalResult(dict(point), value, secs, meta)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def __enter__(self) -> "EvaluationExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
