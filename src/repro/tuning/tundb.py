"""Persistent kernel-tuning database — the "TopHub" artifact.

A tuning run is expensive (many measured configurations); its *answer*
is tiny (one best config per kernel/shape).  ``TuningDB`` persists those
answers so every later serve/train run starts from the tuned
configuration instead of the heuristic default — the pay-once
amortization argument of *Learning to Optimize Tensor Programs*
(TopHub) and *Auto-tuning TensorFlow Threading Model for CPU Backend*
applied to this repo's own Pallas kernels.

Records are keyed by ``(kernel, shape bucket, hardware fingerprint)``:

* **kernel** — registry name (``flash_attention``, ``decode_attention``,
  ``rmsnorm``, ``ssm_scan``, ``gla_scan``);
* **shape bucket** — the kernel's integer call-shape dims, each rounded
  *up* to the next power of two (``bucket_shape``).  A tuned answer for
  ``Sq=4096`` therefore also serves ``Sq=3000..4096`` — tile choices are
  far less shape-sensitive than the measurement cost of re-tuning every
  exact shape, and the kernels clamp/pad tiles anyway;
* **hardware fingerprint** — backend platform, device kind and device
  count (``hardware_fingerprint``).  A measurement taken on one machine
  must never silently configure another: a fingerprint mismatch is a
  *miss*, and the caller falls back to heuristic defaults.

The record value is the best-known config plus provenance::

    {"config": {...tile dims...}, "value": <objective>, "fidelity": 1.0,
     "job_id": "...", "timestamp": <epoch s>, "kernel": "...",
     "bucket": {...}, "fingerprint": {...}}

Storage is the shared :class:`~repro.tuning.cache.JsonCacheStore`
(atomic replace writes + ``flock``-guarded read-merge-write), so
concurrent sweep processes — even on hosts sharing a filesystem — merge
their answers instead of clobbering each other.  ``record`` keeps the
best value per key (an equal-or-worse result never overwrites a stored
answer).

Consumers reach the DB through the ``Runtime.tuning_db`` hook: the
kernel dispatch layer (``repro.kernels.ops``) consults it at **trace
time** with the actual call shapes, so a ``serve_step``/``train_step``
built with a DB picks up tuned tile shapes with zero steady-state
overhead — the lookup happens once per trace, never per step.  With no
DB configured every code path is byte-identical to the historical
behavior.

A ``TuningDB`` instance hashes/compares by identity, so it is a valid
*static* argument of jitted steps (``Runtime`` stays hashable).  The
flip side: the DB is read at trace time, so records added after a step
was traced do not retroactively change that step — rebuild the step (or
construct a fresh ``TuningDB``) to pick up new answers.
"""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Dict, Optional

from repro.tuning.cache import CacheStore, open_store


def _pow2_up(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n) - 1).bit_length()


def bucket_shape(dims: Dict[str, int]) -> Dict[str, int]:
    """Shape-bucketing rule: every positive dim rounds up to a power of
    two; zero/negative dims pass through unchanged."""
    return {k: _pow2_up(v) if isinstance(v, int) and v > 0 else v
            for k, v in dims.items()}


def hardware_fingerprint() -> Dict[str, object]:
    """What a measurement's validity depends on: the machine, not the run.

    ``device_count`` covers the ``--xla_force_host_platform_device_count``
    host-device knob (SNIPPETS.md exemplars): answers tuned under one
    host-device layout do not configure another.
    """
    import jax

    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_kind": str(dev.device_kind),
        "device_count": int(jax.device_count()),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count() or 1,
    }


class TuningDB:
    """Best-known kernel configs keyed by (kernel, bucket, fingerprint).

    ``path=None`` gives an in-memory DB (NullCacheStore: records live
    for the process, nothing persists) — useful for tests and for
    passing a pre-populated ``store``.
    """

    def __init__(self, path=None, *, store: Optional[CacheStore] = None,
                 fingerprint: Optional[Dict] = None):
        if store is not None and path is not None:
            raise ValueError("pass path= or store=, not both")
        self.path = str(path) if path is not None else None
        self.store: CacheStore = store if store is not None else open_store(path)
        self.fingerprint = (dict(fingerprint) if fingerprint is not None
                            else hardware_fingerprint())
        self._cache: Dict[str, dict] = self.store.load()
        self.lookups = 0
        self.hits = 0

    # identity hash/eq: a DB is a valid static arg of jitted steps (the
    # dataclass-generated Runtime.__eq__ compares fields with ==)
    __hash__ = object.__hash__

    def __eq__(self, other) -> bool:
        return self is other

    def __len__(self) -> int:
        return len(self._cache)

    def _key(self, kernel: str, bucket: Dict[str, int]) -> str:
        return json.dumps(
            {"kernel": kernel, "bucket": bucket, "fp": self.fingerprint},
            sort_keys=True)

    def refresh(self) -> None:
        """Re-read the backing store (merge records other writers added).

        Steps traced before the refresh keep their shapes — the DB is
        consulted at trace time (see module docstring).
        """
        for k, v in self.store.load().items():
            self._cache[k] = v

    # -- read side -----------------------------------------------------------
    def lookup(self, kernel: str, dims: Dict[str, int]) -> Optional[dict]:
        """Full record for (kernel, bucket(dims), this fingerprint), or None.

        A hardware-fingerprint mismatch is indistinguishable from an
        absent record on purpose: both mean "no trusted answer here" and
        the caller falls back to heuristic defaults.
        """
        self.lookups += 1
        rec = self._cache.get(self._key(kernel, bucket_shape(dims)))
        if rec is not None:
            self.hits += 1
        return rec

    def kernel_config(self, kernel: str, dims: Dict[str, int]) -> Optional[dict]:
        """Just the tuned config dict (what the dispatch layer overrides
        tile defaults with), or None on a miss."""
        rec = self.lookup(kernel, dims)
        return rec.get("config") if rec is not None else None

    # -- write side ----------------------------------------------------------
    def record(self, kernel: str, dims: Dict[str, int], config: Dict,
               value: float, *, fidelity: float = 1.0,
               job_id: Optional[str] = None,
               timestamp: Optional[float] = None) -> bool:
        """Store ``config`` as the best known for (kernel, bucket(dims))
        unless an existing record already beats ``value``.

        Returns True when the record was written (new key, or a strict
        improvement).  Writes go through the store's locked
        read-merge-write, so concurrent sweeps union their keys; two
        writers racing on the *same* key resolve last-writer-wins, which
        is safe here because both candidates were measured and the next
        ``record`` with the better value restores it.
        """
        bucket = bucket_shape(dims)
        key = self._key(kernel, bucket)
        existing = self._cache.get(key)
        if existing is not None and float(existing["value"]) >= float(value):
            return False
        rec = {
            "config": dict(config),
            "value": float(value),
            "fidelity": float(fidelity),
            "job_id": job_id,
            "timestamp": float(time.time() if timestamp is None else timestamp),
            "kernel": kernel,
            "bucket": bucket,
            "fingerprint": dict(self.fingerprint),
        }
        self._cache[key] = rec
        self.store.put(key, rec)
        return True
