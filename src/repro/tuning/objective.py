"""Explicit objective protocol for the tuning stack.

An *evaluator* maps a point (dict of backend-parameter values) to
``(value, meta)`` — always a 2-tuple, declared by the class attribute
``returns_meta = True``.  Plain value-returning callables (the common
case in tests and synthetic benchmarks) are adapted with
``FunctionEvaluator``; nothing downstream sniffs the return type with
``isinstance(value, tuple)`` any more.

An evaluator that knows its own measurement cost may declare it as
``meta["cost_seconds"]`` (a finite, non-negative number): the executor
records it as the evaluation's ``cost_seconds`` instead of the measured
wall-clock time.  This is the signal BO's cost-aware (EI-per-second)
acquisition trains its cost model on — declare it when the harness can
separate true measurement cost (the compile) from its own overhead, or
when costs are simulated and should stay deterministic.

This module is dependency-light on purpose: the executor and the core
tuner import it without pulling in jax.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple


class Evaluator:
    """Base class for objectives that return ``(value, meta)``.

    ``value`` is the throughput-like objective (higher is better;
    ``-inf`` marks a failed configuration) and ``meta`` is a
    JSON-serializable dict recorded alongside the evaluation.
    """

    returns_meta = True

    def __call__(self, point: Dict) -> Tuple[float, dict]:
        raise NotImplementedError


class FunctionEvaluator(Evaluator):
    """Adapt a plain scalar-returning callable to the (value, meta) protocol."""

    def __init__(self, fn: Callable[[Dict], float]):
        self.fn = fn

    def __call__(self, point: Dict) -> Tuple[float, dict]:
        value = self.fn(point)
        if isinstance(value, tuple):
            raise TypeError(
                "plain objective callables must return a scalar; to attach "
                "metadata, subclass repro.tuning.objective.Evaluator (or set "
                "returns_meta = True) and return (value, meta) explicitly"
            )
        return float(value), {}


class CountingEvaluator(Evaluator):
    """Wrap an evaluator and count real invocations.

    Memoized results (history or disk-backed memo cache) never reach the
    wrapped objective, so ``calls`` is the number of *actual*
    measurements — the quantity a shared memo cache is supposed to drive
    to zero on a repeated run.  Used by the cache-hit acceptance check in
    ``benchmarks/perf_iterations.py`` and the async-loop tests.
    """

    def __init__(self, objective):
        self.inner = as_evaluator(objective)
        self.calls = 0

    def __call__(self, point: Dict) -> Tuple[float, dict]:
        self.calls += 1
        return self.inner(point)


def as_evaluator(objective) -> Evaluator:
    """Normalize any objective to the explicit (value, meta) protocol."""
    if getattr(objective, "returns_meta", False):
        return objective
    return FunctionEvaluator(objective)
