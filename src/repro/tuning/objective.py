"""Explicit objective protocol for the tuning stack.

An *evaluator* maps a point (dict of backend-parameter values) to
``(value, meta)`` — always a 2-tuple, declared by the class attribute
``returns_meta = True``.  Plain value-returning callables (the common
case in tests and synthetic benchmarks) are adapted with
``FunctionEvaluator``; nothing downstream sniffs the return type with
``isinstance(value, tuple)`` any more.

Fidelity protocol
-----------------

An evaluator that can trade measurement cost for measurement quality
declares ``supports_fidelity = True`` and accepts an optional
``fidelity`` keyword in ``__call__``: a float in ``(0, 1]`` giving the
*fraction of a full measurement* to spend.  What the fraction means is
the evaluator's business — iteration count for a wall-clock harness
(``WallClockEvaluator``), analysis depth for a compile-and-analyze
harness (``RooflineEvaluator``), training epochs for a learned model.
The contract is only that:

* ``fidelity=None`` (or ``1.0``) is a **full measurement**: byte-for-byte
  the same behavior as calling the evaluator with no fidelity argument
  at all — the golden sequential traces are pinned against this, so a
  fidelity-capable evaluator must never let a full-fidelity request
  take a different code path than a plain call;
* lower fidelity costs less and may return a noisier/biased value;
* the evaluator reports the fidelity it actually delivered as
  ``meta["fidelity"]`` (the executor fills it in otherwise).

Evaluators that do *not* opt in are always measured at full fidelity:
the executor silently upgrades a low-fidelity request and records
``meta["fidelity"] = 1.0`` so a fidelity scheduler knows it got (and
paid for) the real thing.

Checkpoint-fork protocol (PBT)
------------------------------

An evaluator whose measurements can *continue from where a previous
step left off* — a wall-clock harness that keeps its warmup, a learned
model that keeps its weights — declares ``supports_fork = True`` and
accepts an optional ``resume_state`` keyword: the opaque blob a
previous step returned as ``meta["fork_state"]``.  The contract:

* ``fork_state`` must be **JSON-serializable** — it rides the remote v2
  task payload and the History checkpoint (a remote worker drops
  non-JSON meta with ``meta_error``, losing the lineage's warm start);
* ``resume_state=None`` (or absent) is a cold-start step, byte-for-byte
  the plain call — the golden traces are pinned against this;
* a step given a ``resume_state`` may be cheaper and/or continue an
  accumulating measurement; it returns the *next* ``fork_state`` so the
  lineage (or an exploit-fork clone of it) can continue.

Evaluators that do not opt in still work under PBT: every step is an
independent measurement of the member's current point (the executor
never forwards ``resume_state`` to them).

Cost attribution
----------------

An evaluator that knows its own measurement cost may declare it as
``meta["cost_seconds"]`` (a finite, non-negative number): the executor
records it as the evaluation's ``cost_seconds`` instead of the measured
wall-clock time.  This is the signal BO's cost-aware (EI-per-second)
acquisition trains its cost model on, so the declared number must be
the *recurring, steady-state* cost of measuring this configuration —
the timing loop — and must exclude one-time overhead that a repeat
measurement would not pay again (build, jit/compile, warmup).
``WallClockEvaluator`` declares exactly that; attribute compile time
separately (e.g. ``meta["build_seconds"]``) if it is worth recording.
Declare a cost whenever the harness can separate true measurement cost
from its own overhead, or when costs are simulated and should stay
deterministic.

This module is dependency-light on purpose: the executor and the core
tuner import it without pulling in jax.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple


class Evaluator:
    """Base class for objectives that return ``(value, meta)``.

    ``value`` is the throughput-like objective (higher is better;
    ``-inf`` marks a failed configuration) and ``meta`` is a
    JSON-serializable dict recorded alongside the evaluation.

    Subclasses that can cheapen a measurement set
    ``supports_fidelity = True`` and accept the optional ``fidelity``
    keyword; subclasses that can continue a measurement from a prior
    step's checkpoint set ``supports_fork = True`` and accept the
    optional ``resume_state`` keyword (see the module docstring for
    both contracts).
    """

    returns_meta = True
    supports_fidelity = False
    supports_fork = False

    def __call__(self, point: Dict,
                 fidelity: Optional[float] = None) -> Tuple[float, dict]:
        raise NotImplementedError


class FunctionEvaluator(Evaluator):
    """Adapt a plain scalar-returning callable to the (value, meta) protocol."""

    def __init__(self, fn: Callable[[Dict], float]):
        self.fn = fn

    def __call__(self, point: Dict,
                 fidelity: Optional[float] = None) -> Tuple[float, dict]:
        value = self.fn(point)
        if isinstance(value, tuple):
            raise TypeError(
                "plain objective callables must return a scalar; to attach "
                "metadata, subclass repro.tuning.objective.Evaluator (or set "
                "returns_meta = True) and return (value, meta) explicitly"
            )
        return float(value), {}


class CountingEvaluator(Evaluator):
    """Wrap an evaluator and count real invocations.

    Memoized results (history or disk-backed memo cache) never reach the
    wrapped objective, so ``calls`` is the number of *actual*
    measurements — the quantity a shared memo cache is supposed to drive
    to zero on a repeated run.  Used by the cache-hit acceptance check in
    ``benchmarks/perf_iterations.py`` and the async-loop tests.
    Forwards ``fidelity``/``resume_state`` iff the wrapped evaluator
    supports the respective protocol.
    """

    def __init__(self, objective):
        self.inner = as_evaluator(objective)
        self.calls = 0

    @property
    def supports_fidelity(self) -> bool:
        return self.inner.supports_fidelity

    @property
    def supports_fork(self) -> bool:
        return getattr(self.inner, "supports_fork", False)

    def __call__(self, point: Dict,
                 fidelity: Optional[float] = None,
                 resume_state: Optional[dict] = None) -> Tuple[float, dict]:
        self.calls += 1
        kwargs = {}
        if resume_state is not None and self.supports_fork:
            kwargs["resume_state"] = resume_state
        if self.inner.supports_fidelity:
            return self.inner(point, fidelity=fidelity, **kwargs)
        return self.inner(point, **kwargs)


def as_evaluator(objective) -> Evaluator:
    """Normalize any objective to the explicit (value, meta) protocol."""
    if getattr(objective, "returns_meta", False):
        return objective
    return FunctionEvaluator(objective)
