"""Remote measurement workers: the RPC executor backend.

The tuning loop's dominant cost is the measurement itself, so the last
scale-out move is farming measurements to a fleet of remote hosts while
one tuner keeps the engine, the history, and the memo cache.  This
module is the tuner side of that split: :class:`RemoteWorkerPool`
connects to ``launch/worker.py`` daemons and exposes the same
``Future``-based surface the thread/process pools do, so the whole
executor contract — ``submit`` / ``next_completed`` / ``preempt``,
fidelity/rung tagging, per-evaluation deadlines, exactly-once recording
— works over the wire unchanged.

Wire protocol
-------------

Framing and version negotiation live in ``repro.tuning.protocol``
(length-prefixed JSON; the hello advertises ``max_protocol`` so v2
tuners and v1 workers interoperate — see that module's docstring).
This module re-exports ``send_msg``/``recv_msg``/``parse_address`` for
compatibility with existing imports.

The tuner is the TCP *client*; each worker daemon is a *server* (the
driver is handed ``host:port`` addresses, so workers sit behind plain
listening sockets — no rendezvous service needed).  Per connection:

* handshake — tuner sends ``{"type": "hello", "protocol": 1,
  "max_protocol": 2}``; the worker **registers** with ``{"type":
  "register", "protocol": v, "slots": n, "heartbeat_s": h, "pid": ...,
  "host": ...}`` where ``v`` is the negotiated version.  ``slots`` is
  how many concurrent measurements the worker runs; the pool's
  ``parallelism`` is the fleet-wide sum.  A worker whose objective
  failed to build at startup registers with ``"error": "<traceback
  summary>"`` and zero slots — the pool raises ``ConnectionError``
  naming the import error instead of silently running a broken fleet.
* tasks — tuner sends ``{"type": "task", "id": i, "point": {...},
  "fidelity": f | null, "timeout": t | null}``; the worker *pulls* it
  into its measurement thread pool, runs ``run_objective`` (the exact
  function the local backends run — failures come back as ``-inf`` with
  ``meta["error"]``, never as protocol errors), and streams back
  ``{"type": "result", "id": i, "value": v, "seconds": s,
  "meta": {...}}`` in completion order.
* heartbeats — the worker sends ``{"type": "heartbeat"}`` every
  ``heartbeat_s`` seconds.  The pool declares a worker dead when its
  socket drops *or* no traffic arrives for ``3 * heartbeat_s``, so a
  hung host is caught, not just a closed one.
* ``{"type": "bye"}`` ends the session (either direction).

Failure semantics
-----------------

* **worker death / disconnect** — every task in flight on that worker
  is *reinjected* at the front of the dispatch queue and re-measured by
  a surviving worker.  A disconnect is a property of the fleet, not of
  the configuration: nothing is recorded as a failed config, and
  exactly-once recording holds because a task's ``Future`` resolves at
  most once (a result that raced the disconnect wins; the reinjected
  copy is dropped when its future is already done).  Only when the
  *whole* fleet is gone do outstanding futures fail with
  ``ConnectionError`` — the run cannot proceed and says so loudly.
* **per-eval timeouts** hold across the wire exactly as for the local
  pools: the executor stamps each pending with ``now + timeout`` at
  dispatch and resolves it to ``-inf``/``meta={"timeout": True}`` when
  the deadline passes (the remote measurement is abandoned, its late
  result discarded).  The timeout also rides the task message so a
  harness that *can* stop early may.
* **preemption** — ``future.cancel()`` works natively: a task still in
  the pool's dispatch queue has a PENDING future and cancels cleanly
  (never sent, nothing measured); once dispatched to a worker the
  future is RUNNING, cancel returns False, and the measurement runs to
  completion and is recorded — the same let-it-finish semantics as a
  started pool task.

Cache topology: workers never touch the memo cache.  Results flow back
to the tuner process, which writes them into the shared
``MemoCache``/``CacheStore`` exactly as for local measurements — so
remote and local measurements share one memo and workers need **no
shared filesystem** (the store requirement moved to the tuner host).
"""
from __future__ import annotations

import json
import os
import socket
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

from repro.tuning import protocol as _proto
from repro.tuning.protocol import (  # noqa: F401  (re-exported for compat)
    DEFAULT_HEARTBEAT_S, MAX_FRAME_BYTES, PROTOCOL_V1, PROTOCOL_V2,
    SUPPORTED_PROTOCOLS, parse_address, recv_msg, send_msg,
)

#: historical alias — the version-1 wire format this module debuted with.
PROTOCOL_VERSION = PROTOCOL_V1


# ---------------------------------------------------------------------------
# tuner side: the pool
# ---------------------------------------------------------------------------

class _RemoteTask:
    __slots__ = ("id", "point", "fidelity", "timeout", "future", "dispatched")

    def __init__(self, task_id: int, point: Dict, fidelity, timeout):
        self.id = task_id
        self.point = point
        self.fidelity = fidelity
        self.timeout = timeout
        self.future: Future = Future()
        # True once sent to any worker: the future is RUNNING from then
        # on (let-it-finish preemption), including across a reinjection
        self.dispatched = False


class _WorkerConn:
    __slots__ = ("address", "sock", "slots", "heartbeat_timeout", "inflight",
                 "alive", "last_seen", "pid", "hostname", "protocol")

    def __init__(self, address, sock, slots, heartbeat_timeout, pid, hostname,
                 protocol=PROTOCOL_V1):
        self.address = address
        self.sock = sock
        self.slots = slots
        self.heartbeat_timeout = heartbeat_timeout
        self.inflight: Dict[int, _RemoteTask] = {}
        self.alive = True
        self.last_seen = time.time()
        self.pid = pid
        self.hostname = hostname
        self.protocol = protocol  # negotiated wire version for this session


class RemoteWorkerPool:
    """Futures-speaking pool over remote worker daemons.

    Drop-in for the executor's thread/process pools: ``submit`` returns a
    :class:`concurrent.futures.Future` resolving to the ``(value,
    seconds, meta)`` triple ``run_objective`` produces (the worker runs
    the *same* function), so ``EvaluationExecutor``'s wait, cancel,
    timeout, and exactly-once machinery apply unchanged.

    All workers must be reachable at construction (fail fast on a typo'd
    fleet); mid-run failures are survived by reinjecting that worker's
    in-flight tasks.  There is no reconnect: a dead worker stays dead
    for the life of the pool.
    """

    def __init__(self, addresses: Sequence[str], *,
                 eval_timeout: Optional[float] = None,
                 connect_timeout: float = 10.0):
        if not addresses:
            raise ValueError("remote backend needs at least one "
                             "host:port worker address")
        self.eval_timeout = eval_timeout
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._queue: deque = deque()
        self._seq = 0
        self._shutdown = False
        self._workers: List[_WorkerConn] = []
        deadline = time.time() + connect_timeout
        for addr in addresses:
            self._workers.append(self._connect(addr, deadline))
        self._threads = [
            threading.Thread(target=self._read_loop, args=(w,), daemon=True,
                             name=f"remote-read-{w.address}")
            for w in self._workers
        ]
        self._threads.append(threading.Thread(
            target=self._dispatch_loop, daemon=True, name="remote-dispatch"))
        self._threads.append(threading.Thread(
            target=self._monitor_loop, daemon=True, name="remote-monitor"))
        for t in self._threads:
            t.start()

    # -- connection setup ----------------------------------------------------
    def _connect(self, address: str, deadline: float) -> _WorkerConn:
        host, port = parse_address(address)
        sock = None
        while sock is None:
            try:
                sock = socket.create_connection((host, port), timeout=2.0)
            except OSError as e:
                if time.time() >= deadline:
                    raise ConnectionError(
                        f"cannot reach measurement worker {address}: {e!r} "
                        "(is `launch/worker.py` / --serve-worker running "
                        "there?)") from None
                time.sleep(0.05)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        WorkerServer._enable_keepalive(sock)
        sock.settimeout(10.0)  # handshake only; task reads block forever
        try:
            send_msg(sock, _proto.hello())
            reg = recv_msg(sock)
        except (OSError, ValueError) as e:
            sock.close()
            raise ConnectionError(
                f"handshake with worker {address} failed: {e!r}") from None
        if reg.get("type") != "register" \
                or reg.get("protocol") not in SUPPORTED_PROTOCOLS:
            sock.close()
            raise ConnectionError(
                f"worker {address} spoke {reg.get('type')!r} protocol "
                f"{reg.get('protocol')!r}, expected register/"
                f"{SUPPORTED_PROTOCOLS}")
        if reg.get("error"):
            # the worker came up but its objective did not (bad
            # --objective spec, import failure): fail the pool loudly
            # with the worker's own explanation instead of dispatching
            # to a fleet that can only answer -inf
            sock.close()
            raise ConnectionError(
                f"worker {address} failed at startup: {reg['error']}")
        sock.settimeout(None)
        hb = float(reg.get("heartbeat_s") or DEFAULT_HEARTBEAT_S)
        return _WorkerConn(address, sock, max(1, int(reg.get("slots", 1))),
                           max(3.0 * hb, 1.0), reg.get("pid"),
                           reg.get("host"),
                           protocol=int(reg.get("protocol", PROTOCOL_V1)))

    # -- pool surface (what EvaluationExecutor calls) ------------------------
    @property
    def parallelism(self) -> int:
        """Fleet-wide measurement capacity: slot total of *live* workers
        (a dead worker's slots are gone — advertising them would make
        the driver overfill the queue and starve tasks into their
        per-eval deadlines)."""
        with self._lock:
            return sum(w.slots for w in self._workers if w.alive)

    def alive_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers if w.alive)

    def fleet_health(self) -> List[dict]:
        """Per-worker snapshot (the service's ``job_status`` fleet view)."""
        now = time.time()
        with self._lock:
            return [{"address": w.address, "alive": w.alive,
                     "slots": w.slots, "inflight": len(w.inflight),
                     "protocol": w.protocol, "pid": w.pid, "host": w.hostname,
                     "seconds_since_seen": round(now - w.last_seen, 3)}
                    for w in self._workers]

    def submit(self, fn, objective, point: Dict,
               fidelity: Optional[float] = None) -> Future:
        """Queue one measurement; returns its Future.

        Signature-compatible with ``ThreadPoolExecutor.submit(
        run_objective, objective, point, fidelity)``; ``fn`` and
        ``objective`` are ignored — the worker daemon owns its own
        objective instance (that is the point of the remote backend:
        the objective's heavyweight state lives on the measurement
        host, only points and results cross the wire).
        """
        with self._wake:
            if self._shutdown:
                raise RuntimeError("cannot submit to a shut-down pool")
            if not any(w.alive for w in self._workers):
                # fail loudly NOW: an enqueued task with no worker left
                # to run it would never resolve, and the driver would
                # wait on it forever
                raise ConnectionError(
                    "all remote measurement workers are disconnected; "
                    "cannot dispatch new evaluations")
            self._seq += 1
            task = _RemoteTask(self._seq, dict(point), fidelity,
                               self.eval_timeout)
            self._queue.append(task)
            self._wake.notify_all()
        return task.future

    def shutdown(self, wait: bool = True, cancel_futures: bool = False) -> None:
        with self._wake:
            if self._shutdown:
                return
            self._shutdown = True
            # queued-but-undispatched tasks can never run once the pool
            # is down, so their futures are cancelled regardless of
            # cancel_futures — leaving them PENDING would hang anyone
            # blocked on them.  (The flag keeps the ThreadPoolExecutor-
            # compatible signature; dispatched tasks' futures likewise
            # never resolve after the sockets close.)
            for task in self._queue:
                task.future.cancel()
            self._queue.clear()
            workers = [w for w in self._workers if w.alive]
            self._wake.notify_all()
        for w in workers:
            try:
                send_msg(w.sock, {"type": "bye"})
            except OSError:
                pass
            try:
                w.sock.close()
            except OSError:
                pass
        if wait:
            for t in self._threads:
                t.join(timeout=2.0)

    # -- internals -----------------------------------------------------------
    def _pick(self):
        """Next (task, worker) pair, or None; caller holds the lock."""
        if not self._queue:
            return None
        best = None
        for w in self._workers:
            free = w.slots - len(w.inflight)
            if w.alive and free > 0:
                if best is None or free > (best.slots - len(best.inflight)):
                    best = w
        if best is None:
            return None
        return self._queue.popleft(), best

    def _dispatch_loop(self) -> None:
        while True:
            with self._wake:
                picked = None
                while not self._shutdown and picked is None:
                    picked = self._pick()
                    if picked is None:
                        self._wake.wait(0.1)
                if self._shutdown:
                    return
                task, worker = picked
                worker.inflight[task.id] = task
            # future-state transition and the send happen outside the
            # lock: sendall can block and cancel() takes the future lock
            if task.future.done() or (
                    not task.dispatched
                    and not task.future.set_running_or_notify_cancel()):
                # preempted while queued: never sent, nothing measured
                with self._wake:
                    worker.inflight.pop(task.id, None)
                continue
            task.dispatched = True
            try:
                send_msg(worker.sock, {
                    "type": "task", "id": task.id, "point": task.point,
                    "fidelity": task.fidelity, "timeout": task.timeout,
                })
            except OSError:
                self._on_worker_down(worker)

    def _read_loop(self, worker: _WorkerConn) -> None:
        try:
            while True:
                msg = recv_msg(worker.sock)
                kind = msg.get("type")
                if kind == "result":
                    with self._wake:
                        worker.last_seen = time.time()
                        task = worker.inflight.pop(msg["id"], None)
                        self._wake.notify_all()  # a slot freed up
                    if task is not None and not task.future.done():
                        task.future.set_result(
                            (msg["value"], msg["seconds"], msg["meta"]))
                elif kind == "heartbeat":
                    with self._lock:
                        worker.last_seen = time.time()
                elif kind == "bye":
                    break
        except (ConnectionError, OSError, ValueError):
            pass
        self._on_worker_down(worker)

    def _monitor_loop(self) -> None:
        interval = min((w.heartbeat_timeout for w in self._workers),
                       default=1.0) / 4.0
        interval = min(max(interval, 0.05), 1.0)
        while not self._shutdown:
            time.sleep(interval)
            now = time.time()
            for w in self._workers:
                if w.alive and now - w.last_seen > w.heartbeat_timeout:
                    self._on_worker_down(w)

    def _on_worker_down(self, worker: _WorkerConn) -> None:
        """Mark dead + reinject its in-flight tasks (front of the queue:
        they have been waiting longest and a rung scheduler upstream may
        be blocked on them)."""
        with self._wake:
            if not worker.alive:
                return
            worker.alive = False
            reinject = [t for t in worker.inflight.values()
                        if not t.future.done()]
            worker.inflight.clear()
            self._queue.extendleft(reversed(reinject))
            fleet_down = not any(w.alive for w in self._workers)
            stranded: List[_RemoteTask] = []
            if fleet_down:
                stranded = list(self._queue)
                self._queue.clear()
            self._wake.notify_all()
        try:
            worker.sock.close()
        except OSError:
            pass
        if fleet_down and not self._shutdown:
            err = ConnectionError(
                "all remote measurement workers disconnected; "
                f"{len(stranded)} evaluation(s) stranded")
            for t in stranded:
                if not t.future.done():
                    t.future.set_exception(err)


# ---------------------------------------------------------------------------
# worker side: the daemon server
# ---------------------------------------------------------------------------

class WorkerServer:
    """One measurement host: accepts a tuner, pulls tasks, streams results.

    The daemon owns its objective instance (built once — evaluator state
    like compile caches lives here for the life of the process) and runs
    each task through ``run_objective``, the same isolation wrapper the
    local backends use, on a ``slots``-wide thread pool.  A heartbeat
    rides the connection every ``heartbeat_s`` seconds so the tuner can
    tell a hung host from a busy one.

    Sessions are serial: one tuner at a time, and when it disconnects
    the worker goes back to accepting — so a fleet of daemons survives
    tuner restarts.  Results for tasks still running when a session dies
    are dropped (the tuner reinjected them already); the measurement
    threads are left to finish and the next session gets fresh slots.

    ``start()`` serves on a background thread (tests, in-process
    fleets); ``serve_forever()`` is the daemon entry point.
    """

    def __init__(self, objective, host: str = "127.0.0.1", port: int = 0,
                 slots: int = 1, heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                 startup_error: Optional[str] = None,
                 protocol_ceiling: int = PROTOCOL_V2):
        from repro.tuning.executor import run_objective
        from repro.tuning.objective import as_evaluator

        # bound eagerly, on the main thread: the first task must pay
        # measurement cost only, and an import failure must crash the
        # daemon at startup, not vanish inside a measurement thread.
        # A daemon whose objective could NOT be built still serves in
        # error mode (startup_error set): it registers carrying the
        # import error so the *tuner* fails loudly with the real cause,
        # instead of the fleet looking merely unreachable.
        self._run_objective = run_objective
        self.startup_error = startup_error
        self.protocol_ceiling = int(protocol_ceiling)
        self.objective = (None if startup_error is not None
                          else as_evaluator(objective))
        self.slots = max(1, int(slots))
        self.heartbeat_s = float(heartbeat_s)
        self.handshake_timeout_s = 10.0
        self._lsock = socket.create_server((host, int(port)))
        self.host, self.port = self._lsock.getsockname()[:2]
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._active_conn: Optional[socket.socket] = None
        self.sessions_served = 0

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def serve_forever(self) -> None:
        self._lsock.settimeout(0.5)
        while not self._stop.is_set():
            try:
                conn, _peer = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            self._active_conn = conn
            try:
                self._session(conn)
            except (ConnectionError, OSError, ValueError):
                pass  # tuner went away / spoke garbage: next session
            finally:
                self._active_conn = None
                try:
                    conn.close()
                except OSError:
                    pass

    @staticmethod
    def _enable_keepalive(conn: socket.socket) -> None:
        """A tuner host that dies without FIN (power loss, partition)
        would otherwise leave the session recv blocked for the kernel's
        ~15-minute retransmit timeout — with serial sessions that wedges
        the daemon out of the fleet.  TCP keepalive (tuned to ~minute
        detection where the platform allows) turns it into an ordinary
        connection error and the daemon goes back to accepting."""
        conn.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)
        for opt, val in (("TCP_KEEPIDLE", 30), ("TCP_KEEPINTVL", 10),
                         ("TCP_KEEPCNT", 3)):
            if hasattr(socket, opt):  # Linux; darwin spells idle differently
                conn.setsockopt(socket.IPPROTO_TCP, getattr(socket, opt), val)

    def _session(self, conn: socket.socket) -> None:
        from concurrent.futures import ThreadPoolExecutor

        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._enable_keepalive(conn)
        # handshake under a timeout: sessions are serial, so a stray
        # connection that never says hello (port scan, health probe)
        # must not wedge the accept loop and take this host out of the
        # fleet.  Task reads then block indefinitely — a live tuner is
        # allowed to be quiet, and its death closes the socket.
        conn.settimeout(self.handshake_timeout_s)
        hello = recv_msg(conn)
        version = _proto.negotiate(hello, ceiling=self.protocol_ceiling)
        if version is None:
            send_msg(conn, {"type": "error",
                            "error": f"unsupported hello {hello!r}"})
            return
        register = {
            "type": "register", "protocol": version,
            "slots": self.slots, "heartbeat_s": self.heartbeat_s,
            "pid": os.getpid(), "host": socket.gethostname(),
        }
        if self.startup_error is not None:
            # error mode: tell the tuner WHY this host cannot measure,
            # then end the session (no slots are usable anyway)
            register.update(slots=0, error=self.startup_error)
            send_msg(conn, register)
            return
        send_msg(conn, register)
        conn.settimeout(None)
        self.sessions_served += 1
        send_lock = threading.Lock()
        session_over = threading.Event()

        def heartbeat():
            while not session_over.wait(self.heartbeat_s):
                try:
                    with send_lock:
                        send_msg(conn, {"type": "heartbeat"})
                except OSError:
                    # the peer is unreachable: force the blocked session
                    # recv to error out too, so the daemon returns to
                    # accepting instead of wedging on a dead connection
                    try:
                        conn.shutdown(socket.SHUT_RDWR)
                    except OSError:
                        pass
                    return

        hb = threading.Thread(target=heartbeat, daemon=True)
        hb.start()
        pool = ThreadPoolExecutor(max_workers=self.slots,
                                  thread_name_prefix="measure")
        try:
            while True:
                msg = recv_msg(conn)
                kind = msg.get("type")
                if kind == "task":
                    pool.submit(self._measure, conn, send_lock, msg)
                elif kind == "bye":
                    return
                # unknown message types are ignored: forward-compatible
        finally:
            session_over.set()
            # running measurements are abandoned (their tuner is gone and
            # reinjected them); don't block the accept loop on them
            pool.shutdown(wait=False, cancel_futures=True)

    def _measure(self, conn, send_lock, msg) -> None:
        try:
            value, seconds, meta = self._run_objective(
                self.objective, msg["point"], msg.get("fidelity"))
        except BaseException as e:  # run_objective already catches
            # objective errors; anything reaching here is worker
            # infrastructure breaking — report it rather than going
            # silent (a task that never answers looks like a hang)
            value, seconds = -float("inf"), 0.0
            meta = {"error": f"worker infrastructure failure: {e!r}"}
        try:
            json.dumps(meta, allow_nan=True)
        except (TypeError, ValueError):
            # never let a weird evaluator meta kill the session: the
            # measurement is still real, only its annotations are not
            # transportable
            meta = {"meta_error": "evaluator meta was not "
                                  "JSON-serializable and was dropped"}
        try:
            with send_lock:
                send_msg(conn, {"type": "result", "id": msg["id"],
                                "value": value, "seconds": seconds,
                                "meta": meta})
        except OSError:
            pass  # session died; the tuner reinjects this task elsewhere

    # -- in-process lifecycle (tests / embedded fleets) ----------------------
    def start(self) -> "WorkerServer":
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True, name="worker-serve")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Hard-stop the worker (tests use this to simulate a host dying:
        the active session's socket is closed mid-conversation)."""
        self._stop.set()
        conn = self._active_conn
        if conn is not None:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        try:
            self._lsock.close()
        except OSError:
            pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
