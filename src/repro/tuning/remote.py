"""Remote measurement workers: the RPC executor backend.

The tuning loop's dominant cost is the measurement itself, so the last
scale-out move is farming measurements to a fleet of remote hosts while
one tuner keeps the engine, the history, and the memo cache.  This
module is the tuner side of that split: :class:`RemoteWorkerPool`
connects to ``launch/worker.py`` daemons and exposes the same
``Future``-based surface the thread/process pools do, so the whole
executor contract — ``submit`` / ``next_completed`` / ``preempt``,
fidelity/rung tagging, per-evaluation deadlines, exactly-once recording
— works over the wire unchanged.

Wire protocol
-------------

Framing and version negotiation live in ``repro.tuning.protocol``
(length-prefixed JSON; the hello advertises ``max_protocol`` so v2
tuners and v1 workers interoperate — see that module's docstring).
This module re-exports ``send_msg``/``recv_msg``/``parse_address`` for
compatibility with existing imports.

For the *initial* fleet the tuner is the TCP *client*; each worker
daemon is a *server* (the driver is handed ``host:port`` addresses, so
workers sit behind plain listening sockets — no rendezvous service
needed).  The fleet is also **elastic**: the pool keeps its own listen
socket open for the whole run (``join_address``), and a worker started
later can dial *in* (``launch/worker.py --join host:port``) and
register mid-run — the hello/register handshake and everything after it
are identical in both directions, only who dials differs.  A worker can
also deregister cleanly (``{"type": "leaving"}``): the pool stops
dispatching to it, lets its in-flight measurements finish, then ends
the session — no work is lost and nothing is re-measured.  Per
connection:

* handshake — tuner sends ``{"type": "hello", "protocol": 1,
  "max_protocol": 2}``; the worker **registers** with ``{"type":
  "register", "protocol": v, "slots": n, "heartbeat_s": h, "pid": ...,
  "host": ...}`` where ``v`` is the negotiated version.  At v2 the
  register also ships ``"fingerprint"``, the worker host's
  ``tundb.hardware_fingerprint()`` (v1 workers get a synthetic
  ``unknown`` fingerprint pool-side) — see *hardware-aware scheduling*
  below.  ``slots`` is how many concurrent measurements the worker
  runs; the pool's ``parallelism`` is the fleet-wide sum.  A worker
  whose objective failed to build at startup registers with ``"error":
  "<traceback summary>"`` and zero slots — the pool raises
  ``ConnectionError`` naming the import error instead of silently
  running a broken fleet.
* tasks — tuner sends ``{"type": "task", "id": i, "point": {...},
  "fidelity": f | null, "timeout": t | null}``; the worker *pulls* it
  into its measurement thread pool, runs ``run_objective`` (the exact
  function the local backends run — failures come back as ``-inf`` with
  ``meta["error"]``, never as protocol errors), and streams back
  ``{"type": "result", "id": i, "value": v, "seconds": s,
  "meta": {...}}`` in completion order.
* heartbeats — the worker sends ``{"type": "heartbeat"}`` every
  ``heartbeat_s`` seconds.  The pool declares a worker dead when its
  socket drops *or* no traffic arrives for ``3 * heartbeat_s``, so a
  hung host is caught, not just a closed one.
* ``{"type": "bye"}`` ends the session (either direction).

Failure semantics
-----------------

* **worker death / disconnect** — every task in flight on that worker
  is *reinjected* at the front of the dispatch queue and re-measured by
  a surviving worker.  A disconnect is a property of the fleet, not of
  the configuration: nothing is recorded as a failed config, and
  exactly-once recording holds because a task's ``Future`` resolves at
  most once (a result that raced the disconnect wins; the reinjected
  copy is dropped when its future is already done).  Only when the
  *whole* fleet is gone do outstanding futures fail with
  ``ConnectionError`` — the run cannot proceed and says so loudly.
* **per-eval timeouts** hold across the wire exactly as for the local
  pools: the executor stamps each pending with ``now + timeout`` at
  dispatch and resolves it to ``-inf``/``meta={"timeout": True}`` when
  the deadline passes (the remote measurement is abandoned, its late
  result discarded).  The timeout also rides the task message so a
  harness that *can* stop early may.
* **preemption** — ``future.cancel()`` works natively: a task still in
  the pool's dispatch queue has a PENDING future and cancels cleanly
  (never sent, nothing measured); once dispatched to a worker the
  future is RUNNING, cancel returns False, and the measurement runs to
  completion and is recorded — the same let-it-finish semantics as a
  started pool task.

Speculative straggler re-execution
----------------------------------

A rung's wall clock is its *slowest* measurement, so one slow host
stretches every tail.  The pool tracks observed completion times per
rung (``CompletionStats`` p50/p95 streaming quantiles from
``tuning/fidelity``); when a dispatched task's age exceeds
``speculation_factor * p95`` at its fidelity (after
``min_observations`` completions) and a slot is free with nothing
queued, the monitor dispatches a **duplicate to a different worker**.
First result wins — recorded exactly once under the same at-most-once
future resolution every other path uses; the loser keeps running
remotely (let-it-finish) and its late result is discarded without ever
touching the memo cache or the transfer corpus.  Speculation only
exists in this backend: local backends have no duplicate path at all,
so non-remote runs stay byte-identical.

Hardware-aware scheduling
-------------------------

Measurements taken on different hardware are not comparable, and a
mid-run join makes silent mixing easy.  The pool partitions workers by
register-time fingerprint and, under the default ``strict``
homogeneity, pins the run to the first partition: a static fleet mixing
two fingerprints refuses to construct, and a mismatched joiner is
turned away (counted in ``rejected_joins``).  Under ``normalize`` the
fleet may mix: ``cost_seconds`` from a non-reference partition is
rescaled by a per-partition calibration ratio learned from duplicate
(speculative) completions of the *same task* on both partitions —
``meta["cost_calibration"]`` records the applied factor.  Objective
*values* are never rescaled; only the cost model sees the correction.

Cache topology: workers never touch the memo cache.  Results flow back
to the tuner process, which writes them into the shared
``MemoCache``/``CacheStore`` exactly as for local measurements — so
remote and local measurements share one memo and workers need **no
shared filesystem** (the store requirement moved to the tuner host).
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import platform
import socket
import sys
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.tuning import protocol as _proto
from repro.tuning.fidelity import CompletionStats
from repro.tuning.protocol import (  # noqa: F401  (re-exported for compat)
    DEFAULT_HEARTBEAT_S, MAX_FRAME_BYTES, PROTOCOL_V1, PROTOCOL_V2,
    SUPPORTED_PROTOCOLS, parse_address, recv_msg, send_msg,
)

#: historical alias — the version-1 wire format this module debuted with.
PROTOCOL_VERSION = PROTOCOL_V1

#: what the pool assumes about a worker that registered without a
#: fingerprint (protocol v1, or a pre-elastic daemon): all such workers
#: share one "unknown" partition, so a pure-v1 fleet behaves exactly as
#: it always did under strict homogeneity.
UNKNOWN_FINGERPRINT: Dict[str, object] = {"unknown": True}


def fingerprint_id(fp: Optional[Dict]) -> str:
    """Stable short identity of a hardware fingerprint dict.

    Canonical-JSON hashed: two hosts fingerprint into the same partition
    iff every field matches (that is the point — "close enough" hardware
    is exactly the silent-mixing hole this closes)."""
    if not fp:
        fp = UNKNOWN_FINGERPRINT
    blob = json.dumps(fp, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode("utf-8")).hexdigest()[:12]


#: partition of the workers that reported no fingerprint.  Membership
#: here never pins — or conflicts with — a fleet's hardware partition:
#: "did not report" is not evidence of *different* hardware, and strict
#: mode must keep admitting v1 / pre-elastic daemons.
UNKNOWN_PARTITION = fingerprint_id(UNKNOWN_FINGERPRINT)


def _worker_fingerprint() -> Dict[str, object]:
    """This host's measurement fingerprint for the register handshake.

    ``tundb.hardware_fingerprint()`` when the accelerator stack is
    *already loaded* (its devices are then what this host measures on);
    otherwise a host-level fallback.  The gate on ``sys.modules`` is
    deliberate: worker daemons have been framework-free since the remote
    backend landed, and saying who they are must not cost them a
    multi-second accelerator import at startup."""
    if "jax" in sys.modules:
        try:
            from repro.tuning.tundb import hardware_fingerprint
            return hardware_fingerprint()
        except Exception:
            pass
    return {"backend": "none",
            "device_kind": platform.processor() or "unknown",
            "device_count": 0,
            "machine": platform.machine(),
            "cpu_count": os.cpu_count() or 1}


@dataclass
class FleetOptions:
    """Elastic-fleet knobs for :class:`RemoteWorkerPool`.

    ``listen_port``       pool-side join socket: 0 = ephemeral (default —
                          the socket is open for the whole run, that is
                          what makes the fleet elastic), ``None`` =
                          don't listen (fixed fleet)
    ``listen_host``       interface the join socket binds
    ``speculation``       duplicate suspected stragglers (default on;
                          only the remote backend has this path at all)
    ``speculation_factor``a dispatched task older than ``factor * p95``
                          of its rung's completion times is a straggler
    ``min_observations``  completions at a fidelity before its p95 is
                          trusted (no speculation before that)
    ``homogeneity``       ``"strict"`` (default): one hardware partition
                          per run, mismatched workers refused;
                          ``"normalize"``: mixed partitions allowed,
                          cross-partition cost_seconds rescaled by the
                          learned calibration ratio
    ``heartbeat_s``       fallback heartbeat interval assumed for a
                          worker whose register did not declare one (the
                          stall window is ``3 *`` the per-worker value)
    """

    listen_port: Optional[int] = 0
    listen_host: str = "0.0.0.0"
    speculation: bool = True
    speculation_factor: float = 4.0
    min_observations: int = 4
    homogeneity: str = "strict"
    heartbeat_s: Optional[float] = None

    def __post_init__(self):
        if self.homogeneity not in ("strict", "normalize"):
            raise ValueError(
                f"fleet homogeneity must be 'strict' or 'normalize' "
                f"(got {self.homogeneity!r})")
        if self.speculation_factor <= 1.0:
            raise ValueError(
                f"speculation_factor must exceed 1 "
                f"(got {self.speculation_factor})")


class _FleetCalibration:
    """Per-partition cost calibration learned from duplicate completions.

    When a speculated task completes on two partitions, the pair of raw
    ``seconds`` is one observation of their relative speed.  The factor
    for partition P converts P-measured seconds into reference-partition
    seconds: ``cost_ref = cost_P * factor(P)`` with ``factor =
    exp(mean(log(s_ref / s_P)))`` over observed pairs (geometric mean —
    ratios compose multiplicatively).  Pairs not involving the reference
    partition are ignored; with the realistic two-partition fleet the
    record is complete, and a deeper hierarchy can chain through the
    reference later.
    """

    def __init__(self, reference: Optional[str] = None):
        self.reference = reference
        self._pairs: Dict[str, Tuple[float, int]] = {}  # fp -> (sum_log, n)
        self._lock = threading.Lock()

    def observe(self, fp_a: str, sec_a: float, fp_b: str,
                sec_b: float) -> None:
        """One duplicate pair: the same task measured on two partitions."""
        if (self.reference is None or fp_a == fp_b
                or sec_a <= 0.0 or sec_b <= 0.0
                or not math.isfinite(sec_a) or not math.isfinite(sec_b)):
            return
        if fp_a == self.reference:
            ref_s, other_fp, other_s = sec_a, fp_b, sec_b
        elif fp_b == self.reference:
            ref_s, other_fp, other_s = sec_b, fp_a, sec_a
        else:
            return
        with self._lock:
            s, n = self._pairs.get(other_fp, (0.0, 0))
            self._pairs[other_fp] = (s + math.log(ref_s / other_s), n + 1)

    def factor(self, fp: str) -> float:
        """Multiplier converting fp-partition seconds into reference
        seconds; 1.0 for the reference itself or an uncalibrated
        partition."""
        if fp == self.reference:
            return 1.0
        with self._lock:
            s, n = self._pairs.get(fp, (0.0, 0))
        return math.exp(s / n) if n else 1.0

    def snapshot(self) -> List[dict]:
        """The calibration-ratio record: one row per calibrated
        partition (``ratio`` converts its seconds to reference
        seconds)."""
        with self._lock:
            items = sorted(self._pairs.items())
        return [{"partition": fp, "reference": self.reference,
                 "ratio": round(math.exp(s / n), 6), "n_pairs": n}
                for fp, (s, n) in items if n]


# ---------------------------------------------------------------------------
# tuner side: the pool
# ---------------------------------------------------------------------------

class _RemoteTask:
    __slots__ = ("id", "point", "fidelity", "timeout", "state", "future",
                 "dispatched", "holders", "resolved", "speculated",
                 "spec_holders", "winner")

    def __init__(self, task_id: int, point: Dict, fidelity, timeout,
                 state=None):
        self.id = task_id
        self.point = point
        self.fidelity = fidelity
        self.timeout = timeout
        #: opaque checkpoint-fork blob (protocol v2 ``state`` field);
        #: rides every copy of the task — reinjection, timeout
        #: re-dispatch and speculation must all resume the same lineage
        self.state = state
        self.future: Future = Future()
        # True once sent to any worker: the future is RUNNING from then
        # on (let-it-finish preemption), including across a reinjection
        self.dispatched = False
        #: workers currently holding a copy -> dispatch timestamp.  More
        #: than one entry means a speculative duplicate is in flight.
        self.holders: Dict["_WorkerConn", float] = {}
        #: claimed under the pool lock by the first result — the winner;
        #: every later copy is a loser and is discarded.  (The Future's
        #: own at-most-once semantics are the backstop, but two read
        #: loops racing set_result would make the second raise, so the
        #: claim happens under the lock.)
        self.resolved = False
        #: True once a duplicate was ever dispatched (stats/health)
        self.speculated = False
        #: the workers that received *duplicate* (speculative) copies —
        #: distinguishes "the duplicate won" from "the straggler finished
        #: after all" in the win counter
        self.spec_holders: set = set()
        #: (partition fp_id, raw seconds) of the winning measurement —
        #: pairs with a loser's raw seconds to calibrate partitions
        self.winner: Optional[Tuple[str, float]] = None


def _task_msg(task: "_RemoteTask") -> Dict:
    """Wire form of one task dispatch (shared by the dispatch loop and
    the speculative re-dispatch so every copy carries the same payload).
    ``state`` is a protocol-v2 field and is omitted when absent — v1
    workers never see it because ``_pick``/``_speculate`` only route
    stateful tasks to v2 workers."""
    msg = {"type": "task", "id": task.id, "point": task.point,
           "fidelity": task.fidelity, "timeout": task.timeout}
    if task.state is not None:
        msg["state"] = task.state
    return msg


class _WorkerConn:
    __slots__ = ("address", "sock", "slots", "heartbeat_timeout", "inflight",
                 "alive", "last_seen", "pid", "hostname", "protocol",
                 "fingerprint", "fp_id", "joined_at", "draining", "origin")

    def __init__(self, address, sock, slots, heartbeat_timeout, pid, hostname,
                 protocol=PROTOCOL_V1, fingerprint=None, origin="dial"):
        self.address = address
        self.sock = sock
        self.slots = slots
        self.heartbeat_timeout = heartbeat_timeout
        self.inflight: Dict[int, _RemoteTask] = {}
        self.alive = True
        self.last_seen = time.time()
        self.pid = pid
        self.hostname = hostname
        self.protocol = protocol  # negotiated wire version for this session
        self.fingerprint = dict(fingerprint or UNKNOWN_FINGERPRINT)
        self.fp_id = fingerprint_id(self.fingerprint)
        self.joined_at = time.time()
        #: a worker that sent ``leaving``: no new dispatches, in-flight
        #: measurements run to completion, then the session ends
        self.draining = False
        self.origin = origin  # "dial" (initial fleet) | "join" (elastic)


class RemoteWorkerPool:
    """Futures-speaking pool over remote worker daemons.

    Drop-in for the executor's thread/process pools: ``submit`` returns a
    :class:`concurrent.futures.Future` resolving to the ``(value,
    seconds, meta)`` triple ``run_objective`` produces (the worker runs
    the *same* function), so ``EvaluationExecutor``'s wait, cancel,
    timeout, and exactly-once machinery apply unchanged.

    All *initial* workers must be reachable at construction (fail fast
    on a typo'd fleet); mid-run failures are survived by reinjecting
    that worker's in-flight tasks.  There is no reconnect for a dead
    connection — but the fleet is elastic: the pool's join socket
    (``join_address``) stays open for the whole run, so replacement or
    additional daemons can register at any time (``launch/worker.py
    --join``), and a worker can deregister cleanly with ``leaving``.
    """

    def __init__(self, addresses: Sequence[str], *,
                 eval_timeout: Optional[float] = None,
                 connect_timeout: float = 10.0,
                 fleet: Optional[FleetOptions] = None):
        self.fleet = fleet if fleet is not None else FleetOptions()
        if not addresses and self.fleet.listen_port is None:
            raise ValueError("remote backend needs at least one "
                             "host:port worker address (or a join socket "
                             "— FleetOptions.listen_port — to start empty)")
        self.eval_timeout = eval_timeout
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._queue: deque = deque()
        self._seq = 0
        self._shutdown = False
        self._workers: List[_WorkerConn] = []
        #: pinned/reference hardware partition: the first *reported*
        #: fingerprint id (unknown-partition workers pin nothing).
        #: strict: every other reported fingerprint must match;
        #: normalize: others are admitted and cost-calibrated against it.
        self._partition: Optional[str] = None
        self._calibration = _FleetCalibration()
        self._completion_stats = CompletionStats()
        self._ever_had_workers = False
        # observability counters (fleet_health / bench gates)
        self.speculations = 0       # duplicate dispatches issued
        self.speculation_wins = 0   # tasks a duplicate resolved first
        self.losers_discarded = 0   # late duplicate results dropped
        self.rejected_joins = 0     # joiners refused (strict mismatch, ...)
        self.clean_leaves = 0       # workers that deregistered cleanly
        deadline = time.time() + connect_timeout
        for addr in addresses:
            self._admit(self._connect(addr, deadline), initial=True)
        # the join socket is open for the WHOLE run — that is what makes
        # the fleet elastic (a daemon can register while rungs drain)
        self._listen_sock: Optional[socket.socket] = None
        if self.fleet.listen_port is not None:
            self._listen_sock = socket.create_server(
                (self.fleet.listen_host, int(self.fleet.listen_port)))
        self._threads = [
            threading.Thread(target=self._read_loop, args=(w,), daemon=True,
                             name=f"remote-read-{w.address}")
            for w in self._workers
        ]
        self._threads.append(threading.Thread(
            target=self._dispatch_loop, daemon=True, name="remote-dispatch"))
        self._threads.append(threading.Thread(
            target=self._monitor_loop, daemon=True, name="remote-monitor"))
        if self._listen_sock is not None:
            self._threads.append(threading.Thread(
                target=self._accept_loop, daemon=True, name="remote-accept"))
        for t in self._threads:
            t.start()

    @property
    def join_address(self) -> Optional[str]:
        """``host:port`` a late worker dials to join this fleet, or
        ``None`` for a fixed (non-listening) fleet."""
        if self._listen_sock is None:
            return None
        host, port = self._listen_sock.getsockname()[:2]
        return f"{host}:{port}"

    # -- connection setup ----------------------------------------------------
    def _connect(self, address: str, deadline: float) -> _WorkerConn:
        host, port = parse_address(address)
        sock = None
        while sock is None:
            try:
                sock = socket.create_connection((host, port), timeout=2.0)
            except OSError as e:
                if time.time() >= deadline:
                    raise ConnectionError(
                        f"cannot reach measurement worker {address}: {e!r} "
                        "(is `launch/worker.py` / --serve-worker running "
                        "there?)") from None
                time.sleep(0.05)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        WorkerServer._enable_keepalive(sock)
        sock.settimeout(10.0)  # handshake only; task reads block forever
        try:
            send_msg(sock, _proto.hello())
            reg = recv_msg(sock)
        except (OSError, ValueError) as e:
            sock.close()
            raise ConnectionError(
                f"handshake with worker {address} failed: {e!r}") from None
        if reg.get("type") != "register" \
                or reg.get("protocol") not in SUPPORTED_PROTOCOLS:
            sock.close()
            raise ConnectionError(
                f"worker {address} spoke {reg.get('type')!r} protocol "
                f"{reg.get('protocol')!r}, expected register/"
                f"{SUPPORTED_PROTOCOLS}")
        if reg.get("error"):
            # the worker came up but its objective did not (bad
            # --objective spec, import failure): fail the pool loudly
            # with the worker's own explanation instead of dispatching
            # to a fleet that can only answer -inf
            sock.close()
            raise ConnectionError(
                f"worker {address} failed at startup: {reg['error']}")
        sock.settimeout(None)
        return self._conn_from_register(address, sock, reg, origin="dial")

    def _conn_from_register(self, address, sock, reg,
                            origin="dial") -> _WorkerConn:
        # stall window derived PER WORKER from its registered heartbeat
        # (3 missed beats); the fleet-level heartbeat_s option only fills
        # in for a register that did not declare one
        hb = float(reg.get("heartbeat_s")
                   or self.fleet.heartbeat_s or DEFAULT_HEARTBEAT_S)
        fp = reg.get("fingerprint")
        if not isinstance(fp, dict) or not fp:
            fp = None  # v1 / pre-elastic worker: synthetic unknown partition
        return _WorkerConn(address, sock, max(1, int(reg.get("slots", 1))),
                           max(3.0 * hb, 1.0), reg.get("pid"),
                           reg.get("host"),
                           protocol=int(reg.get("protocol", PROTOCOL_V1)),
                           fingerprint=fp, origin=origin)

    def _admit(self, worker: _WorkerConn, *, initial: bool) -> None:
        """Homogeneity gate + bookkeeping for a registered worker.

        ``initial`` workers that fail the strict gate fail the *pool*
        (a statically mis-assembled fleet is a configuration error);
        joiners are turned away individually (the run goes on with the
        partition it is pinned to).  Raises ``ConnectionError`` on
        rejection — callers close the socket.
        """
        with self._lock:
            if worker.fp_id == UNKNOWN_PARTITION:
                # no fingerprint reported (v1 / pre-elastic daemon):
                # admissible everywhere, pins nothing
                pass
            elif self._partition is None:
                self._partition = worker.fp_id
                self._calibration.reference = worker.fp_id
            elif (worker.fp_id != self._partition
                  and self.fleet.homogeneity == "strict"):
                raise ConnectionError(
                    f"worker {worker.address} is hardware partition "
                    f"{worker.fp_id} ({worker.fingerprint}) but this fleet "
                    f"is pinned to partition {self._partition}; strict "
                    "homogeneity refuses to mix measurements across "
                    "hardware (use --fleet-homogeneity normalize to allow "
                    "a mixed fleet with cost calibration)")
            self._workers.append(worker)
            self._ever_had_workers = True
            self._wake.notify_all()

    # -- elastic joins -------------------------------------------------------
    def _accept_loop(self) -> None:
        try:
            self._listen_sock.settimeout(0.5)
        except OSError:  # shutdown closed the socket before we started
            return
        while not self._shutdown:
            try:
                conn, peer = self._listen_sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            # handshake on a short-lived thread: one stalled joiner must
            # not block the next (nor the run — the accept loop is not on
            # any dispatch path)
            threading.Thread(target=self._handle_join, args=(conn, peer),
                             daemon=True, name="remote-join").start()

    def _handle_join(self, conn: socket.socket, peer) -> None:
        address = f"{peer[0]}:{peer[1]}"
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            WorkerServer._enable_keepalive(conn)
            conn.settimeout(10.0)  # handshake only
            send_msg(conn, _proto.hello())
            reg = recv_msg(conn)
        except (OSError, ValueError):
            try:
                conn.close()
            except OSError:
                pass
            return
        ok = (reg.get("type") == "register"
              and reg.get("protocol") in SUPPORTED_PROTOCOLS
              and not reg.get("error")
              and int(reg.get("slots", 0)) > 0)
        if ok:
            conn.settimeout(None)
            worker = self._conn_from_register(address, conn, reg,
                                              origin="join")
            try:
                self._admit(worker, initial=False)
            except ConnectionError:
                ok = False
        if not ok:
            with self._lock:
                self.rejected_joins += 1
            try:
                send_msg(conn, {"type": "bye"})
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
            return
        t = threading.Thread(target=self._read_loop, args=(worker,),
                             daemon=True, name=f"remote-read-{address}")
        with self._lock:
            self._threads.append(t)
        t.start()

    # -- pool surface (what EvaluationExecutor calls) ------------------------
    @property
    def parallelism(self) -> int:
        """Fleet-wide measurement capacity, **live**: slot total of
        workers that are alive and not draining.  Grows the moment a
        joiner registers and shrinks the moment a worker dies or starts
        leaving — every capacity-sighted loop (async refill, rung drain,
        the service's slot governor) re-reads this each scheduling step,
        never a startup snapshot."""
        with self._lock:
            return sum(w.slots for w in self._workers
                       if w.alive and not w.draining)

    def alive_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers if w.alive)

    @property
    def speculating(self) -> int:
        """Tasks currently running as duplicates (straggler + copy)."""
        with self._lock:
            seen = set()
            for w in self._workers:
                for t in w.inflight.values():
                    if len(t.holders) > 1:
                        seen.add(t.id)
            return len(seen)

    def fleet_health(self) -> List[dict]:
        """Per-worker snapshot (the service's ``job_status`` fleet view)."""
        now = time.time()
        with self._lock:
            rows = []
            for w in self._workers:
                ages = [now - t0 for t in w.inflight.values()
                        for wk, t0 in t.holders.items() if wk is w]
                rows.append({
                    "address": w.address, "alive": w.alive,
                    "slots": w.slots, "inflight": len(w.inflight),
                    "protocol": w.protocol, "pid": w.pid, "host": w.hostname,
                    "seconds_since_seen": round(now - w.last_seen, 3),
                    "fingerprint": dict(w.fingerprint),
                    "partition": w.fp_id,
                    "joined_at": round(w.joined_at, 3),
                    "origin": w.origin,
                    "draining": w.draining,
                    "inflight_age_max": round(max(ages), 3) if ages else 0.0,
                    "speculating": sum(1 for t in w.inflight.values()
                                       if len(t.holders) > 1),
                })
            return rows

    def fleet_stats(self) -> dict:
        """Pool-level elastic/speculation counters + calibration record."""
        with self._lock:
            counters = {
                "speculations": self.speculations,
                "speculation_wins": self.speculation_wins,
                "losers_discarded": self.losers_discarded,
                "rejected_joins": self.rejected_joins,
                "clean_leaves": self.clean_leaves,
                "partition": self._partition,
                "homogeneity": self.fleet.homogeneity,
            }
        counters["speculating"] = self.speculating
        counters["join_address"] = self.join_address
        counters["calibration"] = self._calibration.snapshot()
        counters["completion_times"] = self._completion_stats.snapshot()
        return counters

    def submit(self, fn, objective, point: Dict,
               fidelity: Optional[float] = None,
               state: Optional[dict] = None) -> Future:
        """Queue one measurement; returns its Future.

        Signature-compatible with ``ThreadPoolExecutor.submit(
        run_objective, objective, point, fidelity)``; ``fn`` and
        ``objective`` are ignored — the worker daemon owns its own
        objective instance (that is the point of the remote backend:
        the objective's heavyweight state lives on the measurement
        host, only points and results cross the wire).

        ``state`` is an opaque checkpoint-fork blob (PBT lineages): it
        rides the protocol-v2 task payload as ``resume_state`` for the
        worker's objective, so such tasks only dispatch to v2 workers.
        """
        with self._wake:
            if self._shutdown:
                raise RuntimeError("cannot submit to a shut-down pool")
            if not any(w.alive for w in self._workers):
                if self._ever_had_workers or self._listen_sock is None:
                    # fail loudly NOW: an enqueued task with no worker
                    # left to run it would never resolve, and the driver
                    # would wait on it forever
                    raise ConnectionError(
                        "all remote measurement workers are disconnected; "
                        "cannot dispatch new evaluations")
                # deliberately-empty elastic start (addresses=[] with a
                # join socket): queue until the first daemon registers
            self._seq += 1
            task = _RemoteTask(self._seq, dict(point), fidelity,
                               self.eval_timeout, state)
            self._queue.append(task)
            self._wake.notify_all()
        return task.future

    def shutdown(self, wait: bool = True, cancel_futures: bool = False) -> None:
        with self._wake:
            if self._shutdown:
                return
            self._shutdown = True
            # queued-but-undispatched tasks can never run once the pool
            # is down, so their futures are cancelled regardless of
            # cancel_futures — leaving them PENDING would hang anyone
            # blocked on them.  (The flag keeps the ThreadPoolExecutor-
            # compatible signature; dispatched tasks' futures likewise
            # never resolve after the sockets close.)
            for task in self._queue:
                task.future.cancel()
            self._queue.clear()
            workers = [w for w in self._workers if w.alive]
            threads = list(self._threads)
            self._wake.notify_all()
        if self._listen_sock is not None:
            try:
                self._listen_sock.close()
            except OSError:
                pass
        for w in workers:
            try:
                send_msg(w.sock, {"type": "bye"})
            except OSError:
                pass
            try:
                w.sock.close()
            except OSError:
                pass
        if wait:
            for t in threads:
                t.join(timeout=2.0)

    # -- internals -----------------------------------------------------------
    def _pick(self):
        """Next (task, worker) pair, or None; caller holds the lock.

        A task carrying a checkpoint-fork ``state`` blob may only go to
        a protocol-v2 worker (v1 workers would silently drop the resume
        state and measure a cold start).  The queue is scanned in order
        so a stateful task at the head does not starve stateless work
        that a v1 worker could run right now.
        """
        if not self._queue:
            return None
        best = None
        for w in self._workers:
            free = w.slots - len(w.inflight)
            if w.alive and not w.draining and free > 0:
                if best is None or free > (best.slots - len(best.inflight)):
                    best = w
        if best is None:
            return None
        for i, task in enumerate(self._queue):
            if task.state is None:
                del self._queue[i]
                return task, best
            if best.protocol >= PROTOCOL_V2:
                del self._queue[i]
                return task, best
            # stateful task, best worker is v1: any v2 worker with a
            # free slot can take it instead
            v2 = None
            for w in self._workers:
                free = w.slots - len(w.inflight)
                if (w.alive and not w.draining and free > 0
                        and w.protocol >= PROTOCOL_V2):
                    if v2 is None or free > (v2.slots - len(v2.inflight)):
                        v2 = w
            if v2 is not None:
                del self._queue[i]
                return task, v2
            # no v2 capacity: leave it queued, keep scanning for
            # stateless work the v1 fleet can absorb
        return None

    def _dispatch_loop(self) -> None:
        while True:
            with self._wake:
                picked = None
                while not self._shutdown and picked is None:
                    picked = self._pick()
                    if picked is None:
                        self._wake.wait(0.1)
                if self._shutdown:
                    return
                task, worker = picked
                worker.inflight[task.id] = task
                task.holders[worker] = time.time()
            # future-state transition and the send happen outside the
            # lock: sendall can block and cancel() takes the future lock
            if task.future.done() or (
                    not task.dispatched
                    and not task.future.set_running_or_notify_cancel()):
                # preempted while queued: never sent, nothing measured
                with self._wake:
                    worker.inflight.pop(task.id, None)
                    task.holders.pop(worker, None)
                continue
            task.dispatched = True
            try:
                send_msg(worker.sock, _task_msg(task))
            except OSError:
                self._on_worker_down(worker)

    def _read_loop(self, worker: _WorkerConn) -> None:
        try:
            while True:
                msg = recv_msg(worker.sock)
                kind = msg.get("type")
                if kind == "result":
                    self._on_result(worker, msg)
                elif kind == "heartbeat":
                    with self._lock:
                        worker.last_seen = time.time()
                elif kind == "leaving":
                    # clean deregistration: stop dispatching, let the
                    # in-flight measurements finish, then end the session
                    finish = False
                    with self._wake:
                        worker.draining = True
                        finish = not worker.inflight
                        self._wake.notify_all()
                    if finish:
                        self._finish_leave(worker)
                        break
                elif kind == "bye":
                    break
        except (ConnectionError, OSError, ValueError):
            pass
        self._on_worker_down(worker)

    def _on_result(self, worker: _WorkerConn, msg: dict) -> None:
        now = time.time()
        with self._wake:
            worker.last_seen = now
            task = worker.inflight.pop(msg["id"], None)
            dispatched_at = (task.holders.pop(worker, None)
                             if task is not None else None)
            # first result claims the task under the lock: duplicate
            # completions race through per-worker read loops, and the
            # loser must be identified BEFORE touching the future
            won = task is not None and not task.resolved \
                and not task.future.done()
            if won:
                task.resolved = True
                task.winner = (worker.fp_id, float(msg["seconds"]))
                if worker in task.spec_holders:
                    self.speculation_wins += 1
            elif task is not None:
                self.losers_discarded += 1
            drained = worker.draining and not worker.inflight
            self._wake.notify_all()  # a slot freed up
        if task is None:
            return
        if dispatched_at is not None:
            # dispatch-to-result age feeds the straggler threshold; every
            # real completion counts (losers included — they are honest
            # observations of how long this fleet takes)
            self._completion_stats.record(task.fidelity, now - dispatched_at)
        if won:
            value, seconds, meta = msg["value"], msg["seconds"], msg["meta"]
            if self.fleet.homogeneity == "normalize":
                factor = self._calibration.factor(worker.fp_id)
                if factor != 1.0:
                    seconds = float(seconds) * factor
                    meta = dict(meta or {}, cost_calibration=round(factor, 6))
            task.future.set_result((value, seconds, meta))
        else:
            # loser of a speculative duplicate (or a result for a future
            # the executor already timed out): discarded — it never
            # reaches the memo cache or corpus because it never touches
            # the future.  A cross-partition duplicate pair is exactly
            # one calibration observation.
            if task.winner is not None:
                self._calibration.observe(
                    task.winner[0], task.winner[1],
                    worker.fp_id, float(msg["seconds"]))
        if drained:
            self._finish_leave(worker)

    def _finish_leave(self, worker: _WorkerConn) -> None:
        """End a draining worker's session once its in-flight is empty."""
        try:
            send_msg(worker.sock, {"type": "bye"})
        except OSError:
            pass
        # nothing in flight, nothing to reinject: _on_worker_down just
        # marks it dead and handles the (empty-fleet) stranding rules.
        # The departure is counted only AFTER the alive set shrank (and
        # only by whichever caller actually performed the transition):
        # an observer that sees clean_leaves bump must never still see
        # the leaver in alive_workers().
        if self._on_worker_down(worker):
            with self._lock:
                self.clean_leaves += 1

    def _monitor_loop(self) -> None:
        while not self._shutdown:
            with self._lock:
                timeouts = [w.heartbeat_timeout for w in self._workers
                            if w.alive]
            # re-derived every tick: joiners may have registered with a
            # faster heartbeat than the startup fleet
            interval = min(timeouts, default=1.0) / 4.0
            time.sleep(min(max(interval, 0.05), 1.0))
            now = time.time()
            with self._lock:
                workers = list(self._workers)
            for w in workers:
                if w.alive and now - w.last_seen > w.heartbeat_timeout:
                    self._on_worker_down(w)
            if self.fleet.speculation:
                self._speculate(now)

    def _speculate(self, now: float) -> None:
        """Dispatch duplicates of suspected stragglers onto free slots.

        A dispatched task older than ``speculation_factor * p95`` of its
        rung's observed completion times (``min_observations`` required)
        gets ONE live copy on a *different* worker; first result wins.
        Only truly idle capacity is used: fresh queued work always
        outranks a duplicate (the queue is drained first)."""
        factor = float(self.fleet.speculation_factor)
        min_obs = int(self.fleet.min_observations)
        plan: List[Tuple[_RemoteTask, _WorkerConn]] = []
        with self._wake:
            if self._queue or self._shutdown:
                return
            free = [w for w in self._workers
                    if w.alive and not w.draining
                    and w.slots - len(w.inflight) > 0]
            if not free:
                return
            candidates = []
            for w in self._workers:
                if not w.alive:
                    continue
                for t in w.inflight.values():
                    if t.resolved or len(t.holders) != 1:
                        continue  # done, or already has a live copy
                    n = self._completion_stats.observations(t.fidelity)
                    p95 = self._completion_stats.p95(t.fidelity)
                    if n < min_obs or not p95:
                        continue
                    age = now - t.holders.get(w, now)
                    if age > factor * p95:
                        candidates.append((age, t, w))
            candidates.sort(key=lambda c: -c[0])  # oldest straggler first
            for _age, task, holder in candidates:
                target = None
                for w in sorted(free, key=lambda w: len(w.inflight)):
                    if w is not holder and w not in task.holders \
                            and w.slots - len(w.inflight) > 0 \
                            and (task.state is None
                                 or w.protocol >= PROTOCOL_V2):
                        target = w
                        break
                if target is None:
                    continue
                target.inflight[task.id] = task
                task.holders[target] = now
                task.speculated = True
                task.spec_holders.add(target)
                self.speculations += 1
                plan.append((task, target))
        for task, target in plan:
            try:
                send_msg(target.sock, _task_msg(task))
            except OSError:
                self._on_worker_down(target)

    def _on_worker_down(self, worker: _WorkerConn) -> bool:
        """Mark dead + reinject its in-flight tasks (front of the queue:
        they have been waiting longest and a rung scheduler upstream may
        be blocked on them).  A task whose duplicate is still live on
        another worker is NOT reinjected — the surviving copy resolves
        it (re-dispatching would just add a third measurement).

        Returns True iff *this* call performed the alive->dead
        transition (callers that want to count the departure exactly
        once key off it)."""
        with self._wake:
            if not worker.alive:
                return False
            worker.alive = False
            reinject = []
            for t in worker.inflight.values():
                t.holders.pop(worker, None)
                if not t.resolved and not t.future.done() and not t.holders:
                    reinject.append(t)
            worker.inflight.clear()
            self._queue.extendleft(reversed(reinject))
            fleet_down = not any(w.alive for w in self._workers)
            stranded: List[_RemoteTask] = []
            if fleet_down:
                stranded = list(self._queue)
                self._queue.clear()
            self._wake.notify_all()
        try:
            worker.sock.close()
        except OSError:
            pass
        if fleet_down and not self._shutdown:
            err = ConnectionError(
                "all remote measurement workers disconnected; "
                f"{len(stranded)} evaluation(s) stranded")
            for t in stranded:
                if not t.future.done():
                    t.future.set_exception(err)
        return True


# ---------------------------------------------------------------------------
# worker side: the daemon server
# ---------------------------------------------------------------------------

class WorkerServer:
    """One measurement host: accepts a tuner, pulls tasks, streams results.

    The daemon owns its objective instance (built once — evaluator state
    like compile caches lives here for the life of the process) and runs
    each task through ``run_objective``, the same isolation wrapper the
    local backends use, on a ``slots``-wide thread pool.  A heartbeat
    rides the connection every ``heartbeat_s`` seconds so the tuner can
    tell a hung host from a busy one.

    Sessions are serial: one tuner at a time, and when it disconnects
    the worker goes back to accepting — so a fleet of daemons survives
    tuner restarts.  Results for tasks still running when a session dies
    are dropped (the tuner reinjected them already); the measurement
    threads are left to finish and the next session gets fresh slots.

    ``start()`` serves on a background thread (tests, in-process
    fleets); ``serve_forever()`` is the daemon entry point.  For an
    *elastic* fleet the connection direction flips: ``join(address)`` /
    ``start_join(address)`` dial a running pool's join socket and run
    the exact same session over the dialed-out connection, so a daemon
    started mid-run adds capacity immediately; ``request_leave()``
    deregisters cleanly (the pool stops dispatching, in-flight
    measurements finish, nothing is lost).
    """

    def __init__(self, objective, host: str = "127.0.0.1", port: int = 0,
                 slots: int = 1, heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                 startup_error: Optional[str] = None,
                 protocol_ceiling: int = PROTOCOL_V2,
                 fingerprint: Optional[Dict] = None):
        from repro.tuning.executor import run_objective
        from repro.tuning.objective import as_evaluator

        # bound eagerly, on the main thread: the first task must pay
        # measurement cost only, and an import failure must crash the
        # daemon at startup, not vanish inside a measurement thread.
        # A daemon whose objective could NOT be built still serves in
        # error mode (startup_error set): it registers carrying the
        # import error so the *tuner* fails loudly with the real cause,
        # instead of the fleet looking merely unreachable.
        self._run_objective = run_objective
        self.startup_error = startup_error
        self.protocol_ceiling = int(protocol_ceiling)
        self.objective = (None if startup_error is not None
                          else as_evaluator(objective))
        self.slots = max(1, int(slots))
        self.heartbeat_s = float(heartbeat_s)
        self.handshake_timeout_s = 10.0
        self._lsock = socket.create_server((host, int(port)))
        self.host, self.port = self._lsock.getsockname()[:2]
        # computed after the bind so connecting tuners see an open port
        # while any heavyweight fingerprint import warms up
        self.fingerprint = (dict(fingerprint) if fingerprint is not None
                            else _worker_fingerprint())
        self._stop = threading.Event()
        self._leave = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._active_conn: Optional[socket.socket] = None
        self._session_send_lock: Optional[threading.Lock] = None
        self.sessions_served = 0

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def serve_forever(self) -> None:
        self._lsock.settimeout(0.5)
        while not self._stop.is_set():
            try:
                conn, _peer = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            self._active_conn = conn
            try:
                self._session(conn)
            except (ConnectionError, OSError, ValueError):
                pass  # tuner went away / spoke garbage: next session
            finally:
                self._active_conn = None
                try:
                    conn.close()
                except OSError:
                    pass

    @staticmethod
    def _enable_keepalive(conn: socket.socket) -> None:
        """A tuner host that dies without FIN (power loss, partition)
        would otherwise leave the session recv blocked for the kernel's
        ~15-minute retransmit timeout — with serial sessions that wedges
        the daemon out of the fleet.  TCP keepalive (tuned to ~minute
        detection where the platform allows) turns it into an ordinary
        connection error and the daemon goes back to accepting."""
        conn.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)
        for opt, val in (("TCP_KEEPIDLE", 30), ("TCP_KEEPINTVL", 10),
                         ("TCP_KEEPCNT", 3)):
            if hasattr(socket, opt):  # Linux; darwin spells idle differently
                conn.setsockopt(socket.IPPROTO_TCP, getattr(socket, opt), val)

    def _session(self, conn: socket.socket) -> None:
        from concurrent.futures import ThreadPoolExecutor

        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._enable_keepalive(conn)
        # handshake under a timeout: sessions are serial, so a stray
        # connection that never says hello (port scan, health probe)
        # must not wedge the accept loop and take this host out of the
        # fleet.  Task reads then block indefinitely — a live tuner is
        # allowed to be quiet, and its death closes the socket.
        conn.settimeout(self.handshake_timeout_s)
        hello = recv_msg(conn)
        version = _proto.negotiate(hello, ceiling=self.protocol_ceiling)
        if version is None:
            send_msg(conn, {"type": "error",
                            "error": f"unsupported hello {hello!r}"})
            return
        register = {
            "type": "register", "protocol": version,
            "slots": self.slots, "heartbeat_s": self.heartbeat_s,
            "pid": os.getpid(), "host": socket.gethostname(),
        }
        if version >= PROTOCOL_V2:
            # v2 field: the hardware partition this host measures in
            # (v1 tuners never see it; v1 workers never send it and the
            # pool gives them the synthetic unknown partition)
            register["fingerprint"] = dict(self.fingerprint)
        if self.startup_error is not None:
            # error mode: tell the tuner WHY this host cannot measure,
            # then end the session (no slots are usable anyway)
            register.update(slots=0, error=self.startup_error)
            send_msg(conn, register)
            return
        send_msg(conn, register)
        conn.settimeout(None)
        self.sessions_served += 1
        send_lock = threading.Lock()
        self._session_send_lock = send_lock
        session_over = threading.Event()

        def heartbeat():
            while not session_over.wait(self.heartbeat_s):
                try:
                    with send_lock:
                        send_msg(conn, {"type": "heartbeat"})
                except OSError:
                    # the peer is unreachable: force the blocked session
                    # recv to error out too, so the daemon returns to
                    # accepting instead of wedging on a dead connection
                    try:
                        conn.shutdown(socket.SHUT_RDWR)
                    except OSError:
                        pass
                    return

        hb = threading.Thread(target=heartbeat, daemon=True)
        hb.start()
        pool = ThreadPoolExecutor(max_workers=self.slots,
                                  thread_name_prefix="measure")
        try:
            while True:
                msg = recv_msg(conn)
                kind = msg.get("type")
                if kind == "task":
                    pool.submit(self._measure, conn, send_lock, msg)
                elif kind == "bye":
                    return
                # unknown message types are ignored: forward-compatible
        finally:
            session_over.set()
            self._session_send_lock = None
            # running measurements are abandoned (their tuner is gone and
            # reinjected them); don't block the accept loop on them
            pool.shutdown(wait=False, cancel_futures=True)

    def _measure(self, conn, send_lock, msg) -> None:
        try:
            value, seconds, meta = self._run_objective(
                self.objective, msg["point"], msg.get("fidelity"),
                msg.get("state"))
        except BaseException as e:  # run_objective already catches
            # objective errors; anything reaching here is worker
            # infrastructure breaking — report it rather than going
            # silent (a task that never answers looks like a hang)
            value, seconds = -float("inf"), 0.0
            meta = {"error": f"worker infrastructure failure: {e!r}"}
        try:
            json.dumps(meta, allow_nan=True)
        except (TypeError, ValueError):
            # never let a weird evaluator meta kill the session: the
            # measurement is still real, only its annotations are not
            # transportable
            meta = {"meta_error": "evaluator meta was not "
                                  "JSON-serializable and was dropped"}
        try:
            with send_lock:
                send_msg(conn, {"type": "result", "id": msg["id"],
                                "value": value, "seconds": seconds,
                                "meta": meta})
        except OSError:
            pass  # session died; the tuner reinjects this task elsewhere

    # -- elastic join (worker dials the pool) --------------------------------
    def join(self, address: str, retry_s: Optional[float] = None,
             connect_timeout: float = 10.0) -> None:
        """Dial a running pool's join socket and serve it.

        The session is byte-identical to an accepted one (the pool sends
        hello first in both directions), so everything — slots,
        heartbeats, fingerprint, results — behaves exactly as for a
        dialed-out worker.  ``retry_s=None`` is one-shot (connect
        failures raise, a finished session returns); with a retry
        interval the daemon keeps re-dialing through pool restarts until
        stopped or cleanly left.
        """
        host, port = parse_address(address)
        while not self._stop.is_set():
            try:
                conn = socket.create_connection((host, port),
                                                timeout=connect_timeout)
            except OSError as e:
                if retry_s is None:
                    raise ConnectionError(
                        f"cannot reach tuner pool {address}: {e!r} "
                        "(is the tuner running with a join socket?)"
                    ) from None
                if self._stop.wait(retry_s):
                    return
                continue
            self._active_conn = conn
            try:
                self._session(conn)
            except (ConnectionError, OSError, ValueError):
                pass
            finally:
                self._active_conn = None
                try:
                    conn.close()
                except OSError:
                    pass
            if retry_s is None or self._leave.is_set():
                return
            if self._stop.wait(retry_s):
                return

    def start_join(self, address: str,
                   retry_s: Optional[float] = None) -> "WorkerServer":
        """``join`` on a background thread (tests, embedded fleets)."""
        self._thread = threading.Thread(target=self.join,
                                        args=(address, retry_s),
                                        daemon=True, name="worker-join")
        self._thread.start()
        return self

    def request_leave(self) -> bool:
        """Deregister cleanly from the current session.

        Sends ``leaving``; the pool stops dispatching here, waits for
        this worker's in-flight measurements to stream back, then ends
        the session with ``bye`` — nothing is lost, nothing re-measured.
        Returns False when there is no active session to leave.
        """
        self._leave.set()
        conn, lock = self._active_conn, self._session_send_lock
        if conn is None or lock is None:
            return False
        try:
            with lock:
                send_msg(conn, {"type": "leaving"})
        except OSError:
            return False
        return True

    # -- in-process lifecycle (tests / embedded fleets) ----------------------
    def start(self) -> "WorkerServer":
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True, name="worker-serve")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Hard-stop the worker (tests use this to simulate a host dying:
        the active session's socket is closed mid-conversation)."""
        self._stop.set()
        conn = self._active_conn
        if conn is not None:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        try:
            self._lsock.close()
        except OSError:
            pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
