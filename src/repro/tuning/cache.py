"""Disk-backed cache stores for measurement memoization.

A tuning run's dominant cost is the measurement (lower + compile + run),
not the suggestion, so every completed evaluation is worth persisting:
repeated runs, resumed runs, and multiple hosts sharing a filesystem
should never re-measure a configuration.  This module provides the
storage layer behind both the executor's :class:`MemoCache` and the
``RooflineEvaluator``'s compile cache:

* :class:`CacheStore` — the abstract contract: ``load() -> {key: record}``
  plus ``put(key, record)`` / ``put_many(records)``, where keys are
  strings and records are JSON-serializable dicts.
* :class:`JsonCacheStore` — a single JSON file with **atomic writes**
  (write to a sidecar temp file, then ``os.replace``) and
  **cross-process file locking** (POSIX ``flock`` on a ``.lock``
  sidecar), so concurrent writers on one host — or on several hosts
  sharing a POSIX filesystem with coherent locks — merge their entries
  instead of clobbering each other.  Every ``put`` is read-merge-write
  under the lock: last-writer-wins per key, union across keys.
* :class:`NullCacheStore` — the no-op store used when persistence is
  disabled; keeps callers free of ``if store is not None`` branches.

The on-disk format is a plain JSON object mapping key strings to
records, which is exactly the format the ``RooflineEvaluator`` has
always written — existing cache files load unchanged.
"""
from __future__ import annotations

import contextlib
import json
import os
import pathlib
from typing import Any, Dict

try:  # POSIX file locking; degrade to lockless on platforms without it
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None


class CacheStore:
    """Abstract persistent key->record store (string keys, JSON records)."""

    def load(self) -> Dict[str, Any]:
        raise NotImplementedError

    def put(self, key: str, record: Any) -> None:
        raise NotImplementedError

    def put_many(self, records: Dict[str, Any]) -> None:
        for k, v in records.items():
            self.put(k, v)


class NullCacheStore(CacheStore):
    """Persistence disabled: loads empty, drops every put."""

    def load(self) -> Dict[str, Any]:
        return {}

    def put(self, key: str, record: Any) -> None:
        pass

    def put_many(self, records: Dict[str, Any]) -> None:
        pass


class JsonCacheStore(CacheStore):
    """One JSON file, atomic replace writes, ``flock``-guarded merges."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self.lock_path = self.path.with_name(self.path.name + ".lock")

    @contextlib.contextmanager
    def _locked(self):
        self.lock_path.parent.mkdir(parents=True, exist_ok=True)
        if fcntl is None:  # pragma: no cover - non-POSIX
            yield
            return
        with open(self.lock_path, "w") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def _read(self) -> Dict[str, Any]:
        if not self.path.exists():
            return {}
        text = self.path.read_text()
        if not text.strip():
            return {}
        return json.loads(text)

    def _write(self, data: Dict[str, Any]) -> None:
        tmp = self.path.with_name(self.path.name + ".tmp")
        tmp.write_text(json.dumps(data, default=str))
        os.replace(tmp, self.path)  # atomic: readers never see a torn file

    def load(self) -> Dict[str, Any]:
        with self._locked():
            return self._read()

    def put(self, key: str, record: Any) -> None:
        self.put_many({key: record})

    def put_many(self, records: Dict[str, Any]) -> None:
        if not records:
            return
        with self._locked():
            data = self._read()
            data.update(records)
            self._write(data)


def open_store(path=None) -> CacheStore:
    """``None`` -> :class:`NullCacheStore`; else a :class:`JsonCacheStore`."""
    return NullCacheStore() if path is None else JsonCacheStore(path)
