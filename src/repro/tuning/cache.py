"""Disk-backed cache stores for measurement memoization.

A tuning run's dominant cost is the measurement (lower + compile + run),
not the suggestion, so every completed evaluation is worth persisting:
repeated runs, resumed runs, and multiple hosts sharing a filesystem
should never re-measure a configuration.  This module provides the
storage layer behind both the executor's :class:`MemoCache` and the
``RooflineEvaluator``'s compile cache:

* :class:`CacheStore` — the abstract contract: ``load() -> {key: record}``
  plus ``put(key, record)`` / ``put_many(records)``, where keys are
  strings and records are JSON-serializable dicts.
* :class:`JsonCacheStore` — a single JSON file with **atomic writes**
  (write to a sidecar temp file, then ``os.replace``) and
  **cross-process file locking** (POSIX ``flock`` on a ``.lock``
  sidecar), so concurrent writers on one host — or on several hosts
  sharing a POSIX filesystem with coherent locks — merge their entries
  instead of clobbering each other.  Every ``put``/``put_many`` is one
  read-merge-write under the lock: last-writer-wins per key, union
  across keys (batch the puts — the executor's memo cache flushes once
  per completion drain).  Records are validated JSON-serializable at
  ``put`` time (fail loudly beats a silently corrupting ``default=str``
  round trip), and a corrupt/torn cache file is quarantined to a
  ``.corrupt`` sidecar with a warning instead of killing the run.
* :class:`NullCacheStore` — the no-op store used when persistence is
  disabled; keeps callers free of ``if store is not None`` branches.

The on-disk format is a plain JSON object mapping key strings to
records, which is exactly the format the ``RooflineEvaluator`` has
always written — existing cache files load unchanged.
"""
from __future__ import annotations

import contextlib
import json
import os
import pathlib
import warnings
from typing import Any, Dict

try:  # POSIX file locking; degrade to lockless on platforms without it
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None


def _round_trip_violation(x: Any, path: str = "record"):
    """First node of ``x`` that would NOT survive a JSON round trip
    *equal* (as a description string), or ``None`` if the whole record
    is canonical JSON.

    Stricter than "json.dumps succeeds": a tuple dumps fine but reloads
    as a list, and a non-string dict key reloads stringified — both are
    silent corruption from a cache's point of view, so only the
    canonical JSON types (str/bool/int/float/None, lists of them, and
    string-keyed dicts of them) pass.  This walk is also cheaper than a
    serialization, so validating at ``put`` time costs no extra dumps.
    """
    if x is None or isinstance(x, (str, bool, int, float)):
        return None
    if isinstance(x, list):
        for i, v in enumerate(x):
            bad = _round_trip_violation(v, f"{path}[{i}]")
            if bad:
                return bad
        return None
    if isinstance(x, dict):
        for k, v in x.items():
            if not isinstance(k, str):
                return (f"{path} has non-string key {k!r} "
                        "(reloads stringified)")
            bad = _round_trip_violation(v, f"{path}[{k!r}]")
            if bad:
                return bad
        return None
    return (f"{path} is a {type(x).__name__} (tuples reload as lists; "
            "arbitrary objects do not reload at all)")


def ensure_serializable(key: str, record: Any) -> None:
    """Reject records that would not survive the JSON round trip equal.

    The store used to serialize with ``default=str``, which silently
    stringified anything JSON could not represent — the record *looked*
    persisted but reloaded corrupted (a numpy scalar came back as
    ``"3.0"``, an object as its repr).  A cache whose hits differ from
    what was stored is worse than no cache, so non-round-trippable
    records now fail loudly at ``put`` time, naming the key and the
    offending field.
    """
    try:
        bad = _round_trip_violation(record)
    except RecursionError:
        bad = "record is self-referential"
    if bad:
        raise TypeError(
            f"cache record for key {key!r} would not survive the JSON "
            f"round trip: {bad}; refusing to persist it — a default=str "
            "fallback would silently corrupt the record on reload")


class CacheStore:
    """Abstract persistent key->record store (string keys, JSON records)."""

    def load(self) -> Dict[str, Any]:
        raise NotImplementedError

    def put(self, key: str, record: Any) -> None:
        raise NotImplementedError

    def put_many(self, records: Dict[str, Any]) -> None:
        for k, v in records.items():
            self.put(k, v)


class NullCacheStore(CacheStore):
    """Persistence disabled: loads empty, drops every put."""

    def load(self) -> Dict[str, Any]:
        return {}

    def put(self, key: str, record: Any) -> None:
        pass

    def put_many(self, records: Dict[str, Any]) -> None:
        pass


class JsonCacheStore(CacheStore):
    """One JSON file, atomic replace writes, ``flock``-guarded merges."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self.lock_path = self.path.with_name(self.path.name + ".lock")

    @contextlib.contextmanager
    def _locked(self):
        self.lock_path.parent.mkdir(parents=True, exist_ok=True)
        if fcntl is None:  # pragma: no cover - non-POSIX
            yield
            return
        with open(self.lock_path, "w") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def _read(self) -> Dict[str, Any]:
        if not self.path.exists():
            return {}
        text = self.path.read_text()
        if not text.strip():
            return {}
        try:
            return json.loads(text)
        except json.JSONDecodeError as e:
            # a torn/corrupt file (host died mid-write on a filesystem
            # where rename is not atomic, disk full, truncation) must not
            # kill the whole tuning run: quarantine it for post-mortem and
            # continue with an empty store — the measurements re-accrue
            quarantine = self.path.with_name(self.path.name + ".corrupt")
            try:
                os.replace(self.path, quarantine)
                where = f"quarantined to {quarantine}"
            except OSError:
                where = "and could not be quarantined"
            warnings.warn(
                f"cache file {self.path} is corrupt ({e}); {where}; "
                "continuing with an empty store", RuntimeWarning,
                stacklevel=3)
            return {}

    def _write(self, data: Dict[str, Any]) -> None:
        tmp = self.path.with_name(self.path.name + ".tmp")
        # no default= fallback: put_many validated every record, and a
        # serializer that silently stringifies is how corrupt caches are
        # born (see ensure_serializable)
        tmp.write_text(json.dumps(data, allow_nan=True))
        os.replace(tmp, self.path)  # atomic: readers never see a torn file

    def load(self) -> Dict[str, Any]:
        with self._locked():
            return self._read()

    def put(self, key: str, record: Any) -> None:
        self.put_many({key: record})

    def put_many(self, records: Dict[str, Any]) -> None:
        """One read-merge-write for the whole batch.

        This is the store's flush unit: callers with many pending puts
        (the executor's memo cache batches one flush per completion
        drain) pay one lock + one file traversal for all of them,
        instead of a full read-merge-write per key.
        """
        if not records:
            return
        for k, rec in records.items():
            ensure_serializable(k, rec)
        with self._locked():
            data = self._read()
            data.update(records)
            self._write(data)


def open_store(path=None) -> CacheStore:
    """``None`` -> :class:`NullCacheStore`; else a :class:`JsonCacheStore`."""
    return NullCacheStore() if path is None else JsonCacheStore(path)
