"""Persistent cross-job observation corpus for transfer learning.

Every completed evaluation the executor finalizes is worth more than its
memo entry: a *different* job on a *similar* workload can use it to skip
the from-scratch exploration phase entirely (AutoTVM's "TopHub" insight,
arxiv 1805.08166, and the clustering of near-optimal threading configs
across related CPU workloads in arxiv 1812.01665).  This module is the
storage and similarity layer:

* :class:`TuningCorpus` — append-only record store on the shared
  :class:`~repro.tuning.cache.JsonCacheStore` (atomic replace + flock,
  so concurrent jobs union their observations).  One record per
  completed evaluation: point, value, ``cost_seconds``, fidelity, plus
  the **workload descriptor** of the job that measured it.
* Workload descriptor = task-feature vector (evaluator-declared
  ``task_features()``, e.g. roofline flops/bytes/intensity terms from
  ``cost_model.py`` or traffic stats from ``hlo_analysis.py``) + space
  fingerprint + hardware fingerprint + ``job_id`` + timestamp.
* :func:`workload_distance` — normalized mean per-feature relative
  difference in ``[0, 1]``-ish scale; the knob every consumer (kNN
  neighbor selection, noise inflation, the ``max_distance`` cutoff)
  ranks by.
* :func:`TuningCorpus.prior_observations` — the read side: k-nearest
  neighbor workloads' observations, hard-filtered to the matching
  search-space fingerprint, for surrogate warm-starts and candidate
  pre-filtering.

The corpus is strictly additive: with no corpus configured, nothing in
the tuner consults this module and every trace stays byte-identical.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import platform
import time
import uuid
from typing import Any, Dict, List, Optional

from repro.tuning.cache import CacheStore, JsonCacheStore, NullCacheStore

#: distance penalty added when the observing job ran on different hardware
#: (a config tuned elsewhere is still informative about the *shape* of the
#: landscape, just less trustworthy — soft penalty, not a hard miss like
#: the TuningDB, whose records configure kernels directly)
_HARDWARE_PENALTY = 0.2


def space_fingerprint(space) -> str:
    """Stable short fingerprint of a search space's dimension spec.

    Transfer across *different* spaces is meaningless (points don't even
    validate), so neighbor selection hard-filters on this.
    """
    spec = json.dumps(space.to_dicts(), sort_keys=True)
    return hashlib.sha256(spec.encode()).hexdigest()[:16]


def hardware_descriptor() -> Dict[str, Any]:
    """The TuningDB hardware fingerprint, degraded gracefully: corpus
    writes must not require an importable jax."""
    try:
        from repro.tuning.tundb import hardware_fingerprint
        return hardware_fingerprint()
    except Exception:
        return {"machine": platform.machine(),
                "cpu_count": os.cpu_count() or 1}


def task_features(objective) -> Dict[str, float]:
    """Evaluator-declared task features, coerced to a flat str->float map.

    Evaluators opt in by exposing ``task_features() -> {name: number}``
    (e.g. roofline flops/bytes/arithmetic-intensity terms).  Objectives
    without the hook — plain callables, legacy evaluators — yield ``{}``:
    the corpus still records provenance, and distance falls back to
    "same space = neighbor".
    """
    fn = getattr(objective, "task_features", None)
    if fn is None:
        return {}
    try:
        raw = dict(fn())
    except Exception:
        return {}
    feats: Dict[str, float] = {}
    for k, v in raw.items():
        try:
            f = float(v)
        except (TypeError, ValueError):
            continue
        if math.isfinite(f):
            feats[str(k)] = f
    return feats


def workload_distance(fa: Dict[str, float], fb: Dict[str, float]) -> float:
    """Mean per-feature relative difference over the union of feature keys.

    Per feature: ``|a - b| / (|a| + |b| + eps)`` — 0 for identical, -> 1
    for wildly different magnitudes; a feature one side lacks counts as
    1.0 (maximally uninformative).  Two empty descriptors are distance 0
    (nothing contradicts similarity; the space fingerprint already
    filtered).
    """
    keys = set(fa) | set(fb)
    if not keys:
        return 0.0
    total = 0.0
    for k in keys:
        if k not in fa or k not in fb:
            total += 1.0
        else:
            a, b = fa[k], fb[k]
            total += abs(a - b) / (abs(a) + abs(b) + 1e-12)
    return total / len(keys)


def prediction_agreement(pred, actual) -> Optional[float]:
    """Pearson correlation between predicted and measured values, or
    ``None`` when degenerate (fewer than 2 pairs, or either side
    constant).  The negative-transfer guard drops a prior whose
    agreement is negative: it is actively *mis*-ranking this workload."""
    import numpy as np

    p = np.asarray(pred, dtype=float)
    a = np.asarray(actual, dtype=float)
    if p.size != a.size or p.size < 2:
        return None
    if float(p.std()) == 0.0 or float(a.std()) == 0.0:
        return None
    return float(np.corrcoef(p, a)[0, 1])


class TuningCorpus:
    """Append-only observation corpus shared across tuning jobs.

    Write side: :meth:`describe_job` binds the current job's workload
    descriptor once, then the executor calls :meth:`add` per finalized
    real measurement and :meth:`flush` per completion drain (buffered —
    one locked read-merge-write per drain, same discipline as the memo
    cache).

    Read side: :meth:`prior_observations` returns observations from the
    k nearest *other* workloads on the same search space, each tagged
    with its workload distance.
    """

    def __init__(self, path=None, *, store: Optional[CacheStore] = None,
                 job_id: Optional[str] = None):
        if store is not None:
            self.store = store
        elif path is not None:
            self.store = JsonCacheStore(path)
        else:
            self.store = NullCacheStore()
        self.job_id = job_id or f"job-{os.getpid()}-{int(time.time())}"
        self.descriptor: Optional[Dict[str, Any]] = None
        self._pending: Dict[str, Any] = {}
        self._n_added = 0
        # per-process nonce in every record key: job_ids recur (service
        # crash-resume reuses them; launch/tune.py derives deterministic
        # ones), and the in-process counter restarts at 1, so without the
        # nonce a re-run would overwrite the earlier run's records at the
        # same key indices — put_many merges by key, and "append-only"
        # must mean append-only across processes too
        self._run_nonce = uuid.uuid4().hex[:12]

    # -- write side -----------------------------------------------------------

    def describe_job(self, objective, space) -> Dict[str, Any]:
        """Bind this job's workload descriptor (idempotent)."""
        if self.descriptor is None:
            self.descriptor = {
                "features": task_features(objective),
                "space": space_fingerprint(space),
                "hardware": hardware_descriptor(),
                "job_id": self.job_id,
                "timestamp": time.time(),
            }
        return self.descriptor

    def add(self, point: Dict[str, Any], value: float,
            cost_seconds: float = 0.0, fidelity: float = 1.0) -> None:
        """Buffer one completed evaluation under the bound descriptor."""
        if self.descriptor is None:
            raise RuntimeError("TuningCorpus.add before describe_job: the "
                               "workload descriptor must be bound first")
        self._n_added += 1
        key = json.dumps({"job": self.descriptor["job_id"],
                          "run": self._run_nonce,
                          "space": self.descriptor["space"],
                          "n": self._n_added}, sort_keys=True)
        self._pending[key] = {
            "point": dict(point),
            "value": float(value),
            "cost_seconds": float(cost_seconds),
            "fidelity": float(fidelity),
            "workload": self.descriptor,
        }

    def flush(self) -> None:
        if self._pending:
            self.store.put_many(self._pending)
            self._pending = {}

    # -- read side ------------------------------------------------------------

    def records(self) -> List[Dict[str, Any]]:
        recs = list(self.store.load().values())
        recs.extend(self._pending.values())
        return recs

    def neighbors(self, space, features: Dict[str, float], *,
                  k: int = 3, max_distance: float = 0.35,
                  exclude_job: Optional[str] = None,
                  hardware: Optional[Dict[str, Any]] = None,
                  ) -> List[Dict[str, Any]]:
        """The ``k`` nearest other workloads on this search space.

        Returns ``[{"job_id", "distance", "records": [...]}]`` sorted by
        ascending distance; workloads beyond ``max_distance`` are
        dropped entirely (the deliberate-dissimilarity cutoff — better
        no prior than a misleading one).
        """
        fp = space_fingerprint(space)
        hw = hardware if hardware is not None else hardware_descriptor()
        exclude = exclude_job if exclude_job is not None else self.job_id
        groups: Dict[str, Dict[str, Any]] = {}
        for rec in self.records():
            wl = rec.get("workload") or {}
            if wl.get("space") != fp:
                continue
            jid = wl.get("job_id")
            if jid is None or jid == exclude:
                continue
            g = groups.get(jid)
            if g is None:
                d = workload_distance(features, wl.get("features") or {})
                if wl.get("hardware") != hw:
                    d = min(1.0, d + _HARDWARE_PENALTY)
                g = groups[jid] = {"job_id": jid, "distance": d,
                                   "records": []}
            g["records"].append(rec)
        near = [g for g in groups.values() if g["distance"] <= max_distance]
        near.sort(key=lambda g: (g["distance"], g["job_id"]))
        return near[:k]

    def prior_observations(self, space, features: Dict[str, float], *,
                           k: int = 3, max_rows: int = 32,
                           max_distance: float = 0.35,
                           exclude_job: Optional[str] = None,
                           ) -> List[Dict[str, Any]]:
        """Flat prior-observation rows for surrogate seeding.

        Rows are ``{"point", "value", "cost_seconds", "fidelity",
        "distance"}`` drawn from the k nearest neighbor workloads, at
        most ``max_rows`` total (quota split evenly, spread across each
        workload's value range so the prior keeps both its peaks and its
        floors).  Failed measurements (non-finite values) and points
        that no longer validate against the space are skipped.
        """
        near = self.neighbors(space, features, k=k,
                              max_distance=max_distance,
                              exclude_job=exclude_job)
        if not near:
            return []
        quota = max(1, max_rows // len(near))
        rows: List[Dict[str, Any]] = []
        for g in near:
            usable = []
            for rec in g["records"]:
                v = rec.get("value")
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    continue
                point = rec.get("point")
                if not isinstance(point, dict) or not space.validate(point):
                    continue
                usable.append(rec)
            if not usable:
                continue
            usable.sort(key=lambda r: r["value"])
            if len(usable) > quota:
                # evenly spaced over the value-sorted rows: keeps the
                # best, the worst, and the spread in between
                idx = [round(i * (len(usable) - 1) / (quota - 1))
                       for i in range(quota)] if quota > 1 else [len(usable) - 1]
                usable = [usable[i] for i in sorted(set(idx))]
            for rec in usable:
                rows.append({
                    "point": dict(rec["point"]),
                    "value": float(rec["value"]),
                    "cost_seconds": float(rec.get("cost_seconds", 0.0)),
                    "fidelity": float(rec.get("fidelity", 1.0)),
                    "distance": g["distance"],
                })
        return rows[:max_rows]
