"""The tunable backend-parameter space — the paper's Table 1 analogue.

| paper (TF Intel-CPU backend)      | here (JAX TPU backend)                |
|-----------------------------------|---------------------------------------|
| inter_op_parallelism_threads      | log2_dp  (data-parallel mesh degree)  |
| intra_op / OMP_NUM_THREADS        | tp = chips / dp (cooperating chips)   |
| OMP backend parallelism           | sharding_style: tp vs fsdp_tp (ZeRO)  |
| KMP_BLOCKTIME                     | block_q/block_kv kernel tiles, remat  |
| batch_size                        | microbatches (+ moe capacity factor)  |

``BackendConfig`` is the point the gradient-free engines move through;
``backend_space`` builds the per-arch search space (attention-free archs
drop the attention-tile dims, like the paper's per-model batch ranges).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.models.runtime import REMAT_MODES, Runtime


@dataclass(frozen=True)
class BackendConfig:
    log2_dp: int = 4  # dp = 2**log2_dp; tp = chips_per_pod / dp
    sharding_style: str = "fsdp_tp"  # tp | fsdp_tp
    microbatches: int = 1
    remat: str = "full"  # none | dots | names | full
    block_q: int = 512
    block_kv: int = 512
    scan_chunk: int = 128
    capacity_factor: float = 0.0  # 0 => config default
    opt_state_dtype: str = "f32"  # f32 | bf16
    factored_opt: bool = False
    attn_impl: str = "chunked"  # dry-run lowers the flash-like chunked path
    compute_dtype: str = "bf16"
    unroll_layers: bool = False
    attn_prune: bool = False  # beyond-paper: causal tile skipping
    serve_bf16_params: bool = False  # beyond-paper: bf16 serving weights
    moe_impl: str = "gspmd"  # beyond-paper alt: ep_local (shard_map EP)
    cache_shard: str = "seq"  # decode KV-cache shard dim: seq | heads

    def __post_init__(self):
        # same validated vocabulary as Runtime (the enums drifted once:
        # "names" was tunable here but undocumented there) — reject at
        # construction, where the bad value's origin is still in the
        # traceback, not at some later lowering
        if self.remat not in REMAT_MODES:
            raise ValueError(
                f"unknown remat mode {self.remat!r}; one of {REMAT_MODES}")

    def runtime(self) -> Runtime:
        return Runtime(
            attn_impl=self.attn_impl,
            scan_impl="chunked",
            block_q=self.block_q,
            block_kv=self.block_kv,
            scan_chunk=self.scan_chunk,
            remat=self.remat,
            compute_dtype=self.compute_dtype,
            moe_capacity_factor=self.capacity_factor,
            moe_impl=self.moe_impl,
            unroll_layers=self.unroll_layers,
            attn_prune=self.attn_prune,
        )

    def dp(self, chips_per_pod: int = 256) -> int:
        return min(2 ** self.log2_dp, chips_per_pod)

    def tp(self, chips_per_pod: int = 256) -> int:
        return chips_per_pod // self.dp(chips_per_pod)

    def replace(self, **kw) -> "BackendConfig":
        return dataclasses.replace(self, **kw)


# paper-faithful default: the configuration a savvy user would start from
BASELINE = BackendConfig()

_REMAT = REMAT_MODES  # single source of truth: repro.models.runtime
_STYLES = ("tp", "fsdp_tp")


def backend_space(cfg: ModelConfig, *, kind: str = "train") -> "list[dict]":
    """Search-space description consumed by core.space.SearchSpace.

    Returns a list of dim dicts: {"name", "type": int|cat, "min","max","step"}
    or {"name","type":"cat","choices":[...]}.
    """
    dims = [
        {"name": "log2_dp", "type": "int", "min": 0, "max": 8, "step": 1},
        {"name": "sharding_style", "type": "cat", "choices": list(_STYLES)},
    ]
    if kind == "train":
        dims += [
            {"name": "microbatches", "type": "cat", "choices": [1, 2, 4, 8, 16]},
            {"name": "remat", "type": "cat", "choices": list(_REMAT)},
        ]
    if not cfg.is_attention_free:
        dims += [
            {"name": "block_q", "type": "int", "min": 128, "max": 1024, "step": 128},
            {"name": "block_kv", "type": "int", "min": 128, "max": 1024, "step": 128},
        ]
    if cfg.mamba is not None or cfg.rwkv is not None:
        dims += [
            {"name": "scan_chunk", "type": "int", "min": 32, "max": 256, "step": 32},
        ]
    if cfg.moe is not None:
        dims += [
            {"name": "capacity_factor", "type": "cat",
             "choices": [1.0, 1.25, 1.5, 2.0]},
        ]
    return dims


def config_from_point(point: dict, base: BackendConfig = BASELINE,
                      *, allow_extra: "tuple | frozenset" = (),
                      ) -> BackendConfig:
    """Instantiate a BackendConfig from a tuner point (dict of dim values).

    Point keys that are not ``BackendConfig`` fields raise ``ValueError``:
    silently dropping them meant a typo'd search-space dim (``blok_q``)
    tuned nothing while the search happily burned budget varying it.
    ``allow_extra`` names keys a caller *knowingly* handles outside
    ``BackendConfig`` (e.g. host-level knobs applied by a harness) —
    those are skipped, everything else unknown is an error.
    """
    fields = {f.name for f in dataclasses.fields(BackendConfig)}
    extra = frozenset(allow_extra)
    stray = sorted(k for k in point if k not in fields and k not in extra)
    if stray:
        raise ValueError(
            f"point keys {stray} are not BackendConfig fields "
            f"(known: {sorted(fields)}); a misspelled search-space dim "
            "would otherwise tune nothing — fix the dim name, or pass "
            "allow_extra= for keys genuinely handled elsewhere")
    kw = {k: v for k, v in point.items() if k in fields}
    return dataclasses.replace(base, **kw)
