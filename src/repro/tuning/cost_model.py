"""Three-term roofline for TPU v5e (DESIGN.md §7).

    compute_term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
    memory_term     = HLO_bytes_per_device / HBM_bw            [s]
    collective_term = collective_bytes_per_device / link_bw    [s]

(cost_analysis reports per-device quantities post-SPMD, so dividing the
global numerator by chips x per-chip-rate — the spec formula — is the same
number.)  est_step_time = max of the three; throughput = tokens / est.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
HBM_BYTES = 16e9  # HBM capacity

# collective traffic multipliers (ring algorithms, per-device result bytes)
_KIND_FACTOR = {
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float  # kernel-adjusted HBM traffic (headline term)
    collective_bytes: float  # per device, kind-weighted
    tokens_per_step: float
    chips: int
    model_flops: float = 0.0  # analytic 6*N*D (train) / 2*N*D (serve), global
    memory_per_device: Optional[float] = None
    collective_detail: str = ""
    bytes_hlo_raw: float = 0.0  # spec formula: cost_analysis "bytes accessed"
    bytes_kernel_credit: float = 0.0  # analytic kernel traffic added back

    @property
    def compute_term(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_term(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_term(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def terms(self) -> Dict[str, float]:
        return {
            "compute": self.compute_term,
            "memory": self.memory_term,
            "collective": self.collective_term,
        }

    @property
    def bottleneck(self) -> str:
        t = self.terms
        return max(t, key=t.get)

    @property
    def est_step_time(self) -> float:
        return max(self.terms.values())

    @property
    def throughput(self) -> float:
        """tokens/s at the roofline estimate."""
        t = self.est_step_time
        return self.tokens_per_step / t if t > 0 else float("inf")

    @property
    def roofline_fraction(self) -> float:
        """What fraction of the step is pinned to the compute roof —
        1.0 means perfectly compute-bound (the ceiling)."""
        t = self.est_step_time
        return self.compute_term / t if t > 0 else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the estimated step time."""
        t = self.est_step_time
        if t <= 0 or not self.model_flops:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS_BF16)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — catches remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def fits_hbm(self) -> Optional[bool]:
        if self.memory_per_device is None:
            return None
        return self.memory_per_device <= HBM_BYTES

    def row(self) -> Dict[str, object]:
        return {
            "compute_s": self.compute_term,
            "memory_s": self.memory_term,
            "collective_s": self.collective_term,
            "bottleneck": self.bottleneck,
            "est_step_s": self.est_step_time,
            "throughput_tok_s": self.throughput,
            "roofline_fraction": self.roofline_fraction,
            "mfu": self.mfu,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mem_per_device_GB": (self.memory_per_device or 0) / 1e9,
            "fits_hbm": self.fits_hbm,
            "collectives": self.collective_detail,
            "memory_s_hlo_raw": self.bytes_hlo_raw / HBM_BW,
            "kernel_credit_GB": self.bytes_kernel_credit / 1e9,
        }


def weighted_collective_bytes(bytes_by_kind: Dict[str, int]) -> float:
    return float(sum(_KIND_FACTOR.get(k, 1.0) * v for k, v in bytes_by_kind.items()))


def kernel_traffic_bytes(cfg, shape, bc, chips: int) -> float:
    """Analytic per-device HBM traffic of the Pallas-kernelized regions
    (flash attention / decode attention / ssm / gla scans): what the
    kernels actually move — Q/O once, K/V streamed once per query block,
    scan inputs/outputs once; softmax/scan state stays in VMEM.

    Training multiplies by ~4 (fwd + remat replay + bwd reads/writes);
    prefill/decode by 1.  This credit replaces the CPU-lowered op-chain
    traffic of the tagged ``krnl_`` regions (hlo_analysis.traffic_analysis).
    """
    dp_total = bc.dp() * (2 if chips > 256 else 1)  # batch shards incl. pod
    tp = bc.tp()
    B_dev = max(1, shape.global_batch // min(dp_total, shape.global_batch))
    bpe = 2  # bf16
    train_factor = 4.0 if shape.kind == "train" else 1.0

    def shard(n: int, ways: int) -> float:
        return n / ways if n % ways == 0 else n  # divisibility rule

    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    H_dev = shard(H, tp)
    total = 0.0
    for i in range(cfg.num_layers):
        mk = cfg.mixer_kind(i)
        if mk in ("attn", "mla"):
            if shape.kind == "decode":
                # KV cache read once per token; cache seq shards over tp
                Skv = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
                Skv_dev = shard(Skv, tp)
                if mk == "mla":
                    row = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                    total += B_dev * Skv_dev * row * bpe
                else:
                    total += 2 * B_dev * Skv_dev * K * dh * bpe
                total += 2 * B_dev * H_dev * dh * bpe  # q + out
            else:
                S = shape.seq_len
                nq = max(1, -(-S // bc.block_q))
                qo = 2 * B_dev * S * H_dev * dh * bpe
                if mk == "mla":  # expanded k/v per head in parallel modes
                    kv = 2 * B_dev * S * H_dev * max(dh, cfg.mla.v_head_dim) * bpe
                else:
                    kv = 2 * B_dev * S * shard(K, tp) * dh * bpe
                total += (qo + nq * kv) * train_factor
        elif mk == "mamba":
            d_in = cfg.mamba.expand * cfg.d_model
            d_dev = shard(d_in, tp)
            S = 1 if shape.kind == "decode" else shape.seq_len
            n = cfg.mamba.d_state
            # x, dt, y over d_dev + B, C over d_state, in/out once
            total += (3 * B_dev * S * d_dev + 2 * B_dev * S * n) * bpe * train_factor
        elif mk == "rwkv":
            S = 1 if shape.kind == "decode" else shape.seq_len
            D = cfg.d_model
            total += 5 * B_dev * S * D * bpe * train_factor  # r,k,v,w in; y out
    return float(total)


def analytic_hbm_traffic(cfg, shape, bc, chips: int) -> Dict[str, float]:
    """Per-device, per-step HBM traffic under TPU-grade fusion (the
    "ideal-fused" memory term; DESIGN.md §7).

    Model: every materialized tensor is written once and read once by its
    consumer kernel; elementwise chains fuse; the Pallas-kernelized regions
    contribute their analytic stream traffic (kernel_traffic_bytes).
    Components:
      * params+optimizer — fwd/bwd weight reads, grad write/read, Adam m/v
        read+write, param update (train); one weight read (serve)
      * activations      — per-layer matmul inputs/outputs + norms +
        residuals (+ MoE dispatch/combine buffers), x4 for train
        (fwd + remat replay + ~2x bwd), x1 otherwise
      * logits/CE        — fp32 logits write+read + bwd
      * kernels          — attention/scan streams (kernel_traffic_bytes)
      * carry stack      — remat-saved per-layer residual write+read (train)
    """
    dp_total = bc.dp() * (2 if chips > 256 else 1)
    tp = bc.tp()
    B_dev = max(1, shape.global_batch // min(dp_total, shape.global_batch))
    S = 1 if shape.kind == "decode" else shape.seq_len
    D = cfg.d_model
    bpe = 2.0
    train = shape.kind == "train"
    act_factor = 4.0 if train else 1.0

    def shard(n: int, ways: int) -> float:
        return n / ways if n % ways == 0 else n

    # --- params + optimizer ---
    p_total = cfg.param_counts()["total"]
    p_dev = p_total / chips  # fsdp_tp shards essentially everything
    if bc.sharding_style == "tp":
        p_dev = p_total / tp
    if train:
        opt_bpe = 2 if bc.opt_state_dtype == "bf16" else 4
        # w read fwd + read bwd (4+4, f32 master) + grad write+read (4+4)
        # + m,v read+write (4*opt_bpe) + p write (4)
        params_bytes = p_dev * (4 + 4 + 4 + 4 + 4 * opt_bpe + 4)
    else:
        params_bytes = p_dev * 4  # f32 weights read once per step (baseline)

    # --- per-layer activations ---
    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    H_dev, K_dev = shard(H, tp), shard(K, tp)
    act = 0.0
    for i in range(cfg.num_layers):
        mk, fk = cfg.mixer_kind(i), cfg.mlp_kind(i)
        bsd = B_dev * S * D * bpe
        layer = 4 * bsd  # 2 norms + 2 residual adds (read+write fused pairs)
        if mk in ("attn", "mla"):
            qkv_out = B_dev * S * (H_dev + 2 * K_dev) * dh * bpe
            layer += bsd + qkv_out  # qkv proj in/out
            layer += B_dev * S * H_dev * dh * bpe + bsd  # out proj in/out
        elif mk == "mamba":
            d_in = shard(cfg.mamba.expand * D, tp)
            layer += bsd + 2 * B_dev * S * d_in * bpe  # in_proj
            layer += 2 * B_dev * S * d_in * bpe + bsd  # gate+out_proj
        elif mk == "rwkv":
            layer += 5 * bsd + 2 * bsd  # r,k,v,g,w projections + out
        if cfg.rwkv is not None:
            ff = shard(cfg.d_ff, tp)
            layer += 2 * bsd + 3 * B_dev * S * ff * bpe
        elif fk == "moe":
            m = cfg.moe
            cf = bc.capacity_factor or m.capacity_factor
            tokens_dev = B_dev * S * m.top_k * cf
            ff = m.d_expert if m.num_experts % tp == 0 else shard(m.d_expert, tp)
            layer += B_dev * S * m.num_experts * 4  # router logits
            layer += 2 * tokens_dev * D * bpe * 2  # dispatch + combine buffers
            layer += tokens_dev * (2 * D + 3 * ff) * bpe  # expert mlp streams
        else:
            ff = shard(cfg.d_ff, tp)
            layer += 2 * bsd + 3 * B_dev * S * ff * bpe
        act += layer
    act *= act_factor
    if train:  # remat carry stack: save + re-read layer inputs
        act += 2 * cfg.num_layers * B_dev * shape.seq_len * D * bpe

    # --- logits / CE ---
    V_dev = shard(cfg.padded_vocab, tp)
    S_logit = shape.seq_len if shape.kind == "train" else 1
    logits = B_dev * S_logit * V_dev * (4 + 4)  # f32 write + read
    if train:
        logits *= 2  # bwd pass over logits

    kernels = kernel_traffic_bytes(cfg, shape, bc, chips)
    total = params_bytes + act + logits + kernels
    return {
        "params": float(params_bytes),
        "activations": float(act),
        "logits": float(logits),
        "kernels": float(kernels),
        "total": float(total),
    }


def model_flops(cfg, shape, n_active_params: int) -> float:
    """Analytic MODEL_FLOPS per step: 6*N*D train, 2*N*D inference."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active_params * tokens


def tokens_per_step(shape) -> float:
    if shape.kind == "decode":
        return float(shape.global_batch)
    return float(shape.global_batch * shape.seq_len)
