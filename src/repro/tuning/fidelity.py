"""Fidelity-aware measurement statistics + ASHA back-compat shim.

The ASHA ``RungScheduler`` that used to live here moved to
``repro.tuning.schedulers.asha`` when the scheduler seam was extracted
(see that package: HyperBand and PBT now share its driver).  The
historical import path is kept working as a plain re-export:

    from repro.tuning.fidelity import RungScheduler   # still fine

What *lives* here is the fidelity-keyed completion-time bookkeeping the
remote pool uses for straggler detection — ``StreamingQuantiles`` and
``CompletionStats`` — which is about measurements, not scheduling
policy.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional


class StreamingQuantiles:
    """Bounded-memory quantile tracker for completion times.

    Keeps the most recent ``max_samples`` observations in a ring buffer
    and answers quantile queries from a sorted copy — O(n log n) on a
    few hundred floats, called a few times per second at most.  Recency
    weighting is deliberate: a fleet's speed changes when workers join
    or leave, and stale samples from a departed slow host must not keep
    inflating the straggler threshold forever.

    Thread-safe: the remote pool's read loops ``add`` from one thread
    per worker while the monitor loop queries.
    """

    def __init__(self, max_samples: int = 256):
        self._max = max(8, int(max_samples))
        self._ring: List[float] = []
        self._next = 0
        self._count = 0  # lifetime observation count (never decays)
        self._lock = threading.Lock()

    def add(self, x: float) -> None:
        x = float(x)
        if not math.isfinite(x) or x < 0.0:
            return
        with self._lock:
            if len(self._ring) < self._max:
                self._ring.append(x)
            else:
                self._ring[self._next] = x
                self._next = (self._next + 1) % self._max
            self._count += 1

    @property
    def n(self) -> int:
        """Lifetime observations (not just the retained window)."""
        return self._count

    def quantile(self, q: float) -> Optional[float]:
        """q-quantile of the retained window (nearest-rank), or ``None``
        with no observations."""
        with self._lock:
            if not self._ring:
                return None
            s = sorted(self._ring)
        q = min(1.0, max(0.0, float(q)))
        idx = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
        return s[idx]

    def p50(self) -> Optional[float]:
        return self.quantile(0.50)

    def p95(self) -> Optional[float]:
        return self.quantile(0.95)


class CompletionStats:
    """Per-rung observed completion times for straggler detection.

    Rungs are keyed by their fidelity (the ladder maps rung <-> fidelity
    one-to-one, and fidelity is what actually crosses the wire to the
    measurement workers), so the remote pool can record without knowing
    scheduler internals.  ``None`` fidelity — the single-fidelity path —
    gets its own bucket.
    """

    def __init__(self, max_samples: int = 256):
        self._max_samples = max_samples
        self._by_key: Dict[float, StreamingQuantiles] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(fidelity: Optional[float]) -> float:
        return 1.0 if fidelity is None else round(float(fidelity), 9)

    def _bucket(self, fidelity: Optional[float]) -> StreamingQuantiles:
        key = self._key(fidelity)
        with self._lock:
            q = self._by_key.get(key)
            if q is None:
                q = self._by_key[key] = StreamingQuantiles(self._max_samples)
            return q

    def record(self, fidelity: Optional[float], seconds: float) -> None:
        self._bucket(fidelity).add(seconds)

    def observations(self, fidelity: Optional[float]) -> int:
        key = self._key(fidelity)
        with self._lock:
            q = self._by_key.get(key)
        return 0 if q is None else q.n

    def p50(self, fidelity: Optional[float]) -> Optional[float]:
        key = self._key(fidelity)
        with self._lock:
            q = self._by_key.get(key)
        return None if q is None else q.p50()

    def p95(self, fidelity: Optional[float]) -> Optional[float]:
        key = self._key(fidelity)
        with self._lock:
            q = self._by_key.get(key)
        return None if q is None else q.p95()

    def snapshot(self) -> List[dict]:
        """JSON-able per-rung summary (fleet_health / bench artifacts)."""
        with self._lock:
            items = sorted(self._by_key.items())
        return [{"fidelity": k, "n": q.n,
                 "p50": q.p50(), "p95": q.p95()} for k, q in items]


# back-compat: the ASHA scheduler moved behind the TrialScheduler seam
from repro.tuning.schedulers.asha import RungScheduler, RungState  # noqa: E402

__all__ = ["CompletionStats", "RungScheduler", "RungState",
           "StreamingQuantiles"]
