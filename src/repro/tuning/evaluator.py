"""Objective evaluators — the "system under test" side of paper Fig. 4.

* ``WallClockEvaluator`` — the paper-faithful measurement path: apply the
  configuration, run the jitted step on the local device(s), report
  measured throughput (examples- or tokens-/second).
* ``RooflineEvaluator`` — the TPU-shaped path for this CPU-only container:
  lower+compile the production-mesh program for the configuration and
  report the roofline-estimated throughput (tokens/second).  A
  configuration whose per-device footprint exceeds HBM is a *failed run*
  (-inf), exactly like a crashed measurement in the paper's harness.
  ``cache_path`` persists every compile+analysis through the shared
  :class:`~repro.tuning.cache.JsonCacheStore` (atomic writes,
  cross-process file locking), so concurrent tuning runs — even on
  different hosts sharing a filesystem — merge their measurements
  instead of clobbering each other; the on-disk format is unchanged
  from the historical plain-JSON cache.

Both implement the explicit evaluator protocol
(``repro.tuning.objective.Evaluator``): ``__call__(point) -> (value,
meta)``, declared via ``returns_meta = True`` so the tuner/executor never
have to sniff return types.  Both also opt into the **fidelity**
protocol (``supports_fidelity``) for multi-fidelity tuning:
``WallClockEvaluator`` scales its variance-adaptive timing loop,
``RooflineEvaluator`` drops to the fast (single-compile, trip-scaled)
analysis depth; in both, a full-fidelity request takes exactly the same
code path as a plain no-fidelity call.  (Note the *measurement loop
itself* changed in this revision: ``WallClockEvaluator`` now defaults to
variance-adaptive timing — pass ``adaptive=False`` for the historical
fixed-``iters`` loop.)
"""
from __future__ import annotations

import json
import math
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.tuning.cache import CacheStore, open_store
from repro.tuning.cost_model import HBM_BYTES
from repro.tuning.objective import Evaluator
from repro.tuning.parameters import BASELINE, BackendConfig, config_from_point


class RooflineEvaluator(Evaluator):
    def __init__(
        self,
        arch: str,
        shape_name: str,
        *,
        multi_pod: bool = False,
        chips_per_pod: int = 256,
        base: BackendConfig = BASELINE,
        hbm_bytes: float = HBM_BYTES,
        cache_path: Optional[str] = None,
    ):
        self.arch = arch
        self.shape_name = shape_name
        self.multi_pod = multi_pod
        self.chips_per_pod = chips_per_pod
        self.base = base
        self.hbm_bytes = hbm_bytes
        # the shared store is loaded exactly once here; later in-memory
        # misses re-consult it (a locked file read) before compiling, so
        # entries written by concurrent hosts after startup are reused
        self.store: CacheStore = open_store(cache_path)
        self._cache: Dict[str, dict] = self.store.load()

    supports_fidelity = True

    def _key(self, bc: BackendConfig, fast: bool = False) -> str:
        d = {"arch": self.arch, "shape": self.shape_name, "mp": self.multi_pod,
             "bc": bc.__dict__}
        if fast:  # full-fidelity keys keep the historical format unchanged
            d["analysis"] = "fast"
        return json.dumps(d, sort_keys=True)

    def __call__(self, point: Dict,
                 fidelity: Optional[float] = None) -> Tuple[float, dict]:
        from repro.launch.dryrun import analyze_cell  # lazy: sets XLA_FLAGS

        # analysis-depth fidelity: a partial measurement drops the unrolled
        # 1-/2-period cost compiles (``fast`` analysis — trip-count scaling,
        # a documented few-% overcount) instead of the exact extrapolation,
        # cutting the per-point compile count from three to one
        fast = fidelity is not None and fidelity < 1.0
        bc = config_from_point(point, self.base)
        key = self._key(bc, fast=fast)
        rec = self._cache.get(key)
        if rec is None:
            # in-memory miss: another host sharing this store may have
            # compiled it since __init__ — a locked file read is orders of
            # magnitude cheaper than a recompile.  The whole snapshot was
            # just parsed anyway, so merge every entry we don't already
            # hold: each concurrent-host record then costs one file read
            # total, not one per miss
            for k, v in self.store.load().items():
                self._cache.setdefault(k, v)
            rec = self._cache.get(key)
        if rec is None:
            rec = analyze_cell(
                self.arch, self.shape_name, multi_pod=self.multi_pod,
                bc=bc, chips_per_pod=self.chips_per_pod, fast=fast,
            )
            self._cache[key] = rec
            # merge-on-write under the store's file lock: concurrent tuning
            # runs sharing one cache file union their entries
            self.store.put(key, rec)
        # a full-fidelity request is byte-identical to a plain call,
        # meta included; only partial measurements are labeled
        fid_meta = {"fidelity": float(fidelity)} if fast else {}
        if rec.get("skipped"):
            return -math.inf, dict(fid_meta, skip_reason=rec["skip_reason"])
        mem = rec["memory"]["per_device_B"]
        meta = dict(fid_meta, roofline=rec["roofline"], mem_per_device_B=mem)
        if mem > self.hbm_bytes:
            return -math.inf, dict(meta, oom=True)
        return float(rec["roofline"]["throughput_tok_s"]), meta


#: two-sided 95% Student-t critical values by degrees of freedom (1-30);
#: beyond 30 the normal 1.96 is within ~2%
_T95 = (12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
        2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
        2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
        2.048, 2.045, 2.042)


def _t95(df: int) -> float:
    return _T95[df - 1] if 1 <= df <= len(_T95) else 1.96


class WallClockEvaluator(Evaluator):
    """Measured throughput of a step built from the configuration point.

    ``make_step(point) -> (step_fn, args, examples_per_step)``:
    the builder applies the point's backend parameters (Runtime knobs,
    microbatches, ...) and returns a jittable step plus its inputs.

    Measurement is **variance-adaptive**: steps are timed one at a time
    until the 95% confidence half-width of the mean step time is within
    ``rel_halfwidth`` of the mean, or ``max_iters`` measurements were
    taken — so a stable configuration stops after ``min_iters`` steps
    while a jittery one keeps measuring up to the cap.  The caps default
    off the caller's ``iters`` (``min_iters = 2`` — the CI needs two
    samples — and ``max_iters = 4 * iters``), so a harness sized for cheap
    measurements stays cheap: ``iters=3`` now usually costs 2 steps and
    never more than 12.  Note the methodology: per-step variance needs a
    per-step ``block_until_ready``, so each sample includes one
    host/device sync that the historical pipelined loop amortized across
    ``iters`` steps — for sub-millisecond steps this inflates
    ``step_seconds`` slightly and uniformly.  ``adaptive=False`` restores
    the historical fixed-``iters`` pipelined loop exactly (use it when
    numbers must be comparable with pre-adaptive runs).

    Fidelity (``supports_fidelity``): a partial measurement scales the
    iteration cap by ``fidelity`` and widens the target CI by
    ``1/fidelity`` — the bottom successive-halving rung is a couple of
    quick steps with a loose interval, the top rung the full adaptive
    loop.  ``fidelity=None``/1.0 is byte-identical to a plain call.

    Cost attribution: ``meta["cost_seconds"]`` is the **measurement-only**
    time (the timing loop), excluding step build, jit lowering/compile,
    and warmup — a repeat measurement of this configuration pays only the
    timing loop, so charging compile to the configuration would mislead
    cost-aware (EI-per-second) acquisition.  The one-time overhead is
    reported separately as ``meta["build_seconds"]``.
    """

    supports_fidelity = True

    def __init__(
        self,
        make_step: Callable[[Dict], Tuple[Callable, tuple, float]],
        *,
        warmup: int = 1,
        iters: int = 3,
        adaptive: bool = True,
        rel_halfwidth: float = 0.05,
        min_iters: Optional[int] = None,
        max_iters: Optional[int] = None,
    ):
        self.make_step = make_step
        self.warmup = warmup
        self.iters = iters
        self.adaptive = adaptive
        self.rel_halfwidth = rel_halfwidth
        # caps scale with the caller's iters so harnesses sized for cheap
        # measurements stay cheap; the CI needs >= 2 samples for a
        # variance estimate, so 2 is the floor either way
        self.max_iters = max(2, 4 * iters if max_iters is None else max_iters)
        self.min_iters = min(self.max_iters,
                             max(2, 2 if min_iters is None else min_iters))

    def _measure(self, jitted, args, fidelity: float):
        """Adaptive timing loop: per-step seconds list."""
        if not self.adaptive:
            n = max(1, round(self.iters * fidelity))
            t0 = time.perf_counter()
            out = None
            for _ in range(n):
                out = jitted(*args)
            jax.block_until_ready(out)
            return [(time.perf_counter() - t0) / n] * n
        cap = max(self.min_iters, math.ceil(self.max_iters * fidelity))
        target = self.rel_halfwidth / fidelity
        times = []
        while len(times) < cap:
            t0 = time.perf_counter()
            jax.block_until_ready(jitted(*args))
            times.append(time.perf_counter() - t0)
            n = len(times)
            if n < self.min_iters:
                continue
            mean = sum(times) / n
            var = sum((t - mean) ** 2 for t in times) / (n - 1)
            halfwidth = _t95(n - 1) * math.sqrt(var / n)
            if halfwidth <= target * mean:
                break
        return times

    def __call__(self, point: Dict,
                 fidelity: Optional[float] = None) -> Tuple[float, dict]:
        f = 1.0 if fidelity is None else max(min(float(fidelity), 1.0), 1e-3)
        t_build0 = time.perf_counter()
        step, args, examples = self.make_step(point)
        jitted = jax.jit(step)
        out = None
        for _ in range(self.warmup):
            out = jitted(*args)
        jax.block_until_ready(out)
        build_seconds = time.perf_counter() - t_build0
        times = self._measure(jitted, args, f)
        n = len(times)
        dt = sum(times) / n
        mean = dt
        hw = 0.0
        if n >= 2:
            var = sum((t - mean) ** 2 for t in times) / (n - 1)
            hw = _t95(n - 1) * math.sqrt(var / n)
        meta = {
            "step_seconds": dt,
            "iters": n,
            "ci_rel_halfwidth": hw / mean if mean > 0 else 0.0,
            "build_seconds": build_seconds,
            # measurement-only cost: what a repeat measurement would pay
            "cost_seconds": float(sum(times)),
        }
        if f < 1.0:  # a full-fidelity request is byte-identical to a
            meta["fidelity"] = f  # plain call, meta included
        return examples / dt, meta
