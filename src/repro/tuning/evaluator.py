"""Objective evaluators — the "system under test" side of paper Fig. 4.

* ``WallClockEvaluator`` — the paper-faithful measurement path: apply the
  configuration, run the jitted step on the local device(s), report
  measured throughput (examples- or tokens-/second).
* ``RooflineEvaluator`` — the TPU-shaped path for this CPU-only container:
  lower+compile the production-mesh program for the configuration and
  report the roofline-estimated throughput (tokens/second).  A
  configuration whose per-device footprint exceeds HBM is a *failed run*
  (-inf), exactly like a crashed measurement in the paper's harness.
  ``cache_path`` persists every compile+analysis through the shared
  :class:`~repro.tuning.cache.JsonCacheStore` (atomic writes,
  cross-process file locking), so concurrent tuning runs — even on
  different hosts sharing a filesystem — merge their measurements
  instead of clobbering each other; the on-disk format is unchanged
  from the historical plain-JSON cache.

Both implement the explicit evaluator protocol
(``repro.tuning.objective.Evaluator``): ``__call__(point) -> (value,
meta)``, declared via ``returns_meta = True`` so the tuner/executor never
have to sniff return types.
"""
from __future__ import annotations

import json
import math
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.tuning.cache import CacheStore, open_store
from repro.tuning.cost_model import HBM_BYTES
from repro.tuning.objective import Evaluator
from repro.tuning.parameters import BASELINE, BackendConfig, config_from_point


class RooflineEvaluator(Evaluator):
    def __init__(
        self,
        arch: str,
        shape_name: str,
        *,
        multi_pod: bool = False,
        chips_per_pod: int = 256,
        base: BackendConfig = BASELINE,
        hbm_bytes: float = HBM_BYTES,
        cache_path: Optional[str] = None,
    ):
        self.arch = arch
        self.shape_name = shape_name
        self.multi_pod = multi_pod
        self.chips_per_pod = chips_per_pod
        self.base = base
        self.hbm_bytes = hbm_bytes
        self.store: CacheStore = open_store(cache_path)
        self._cache: Dict[str, dict] = self.store.load()

    def _key(self, bc: BackendConfig) -> str:
        return json.dumps(
            {"arch": self.arch, "shape": self.shape_name, "mp": self.multi_pod,
             "bc": bc.__dict__}, sort_keys=True)

    def __call__(self, point: Dict) -> Tuple[float, dict]:
        from repro.launch.dryrun import analyze_cell  # lazy: sets XLA_FLAGS

        bc = config_from_point(point, self.base)
        key = self._key(bc)
        if key in self._cache:
            rec = self._cache[key]
        else:
            rec = analyze_cell(
                self.arch, self.shape_name, multi_pod=self.multi_pod,
                bc=bc, chips_per_pod=self.chips_per_pod,
            )
            self._cache[key] = rec
            # merge-on-write under the store's file lock: concurrent tuning
            # runs sharing one cache file union their entries
            self.store.put(key, rec)
        if rec.get("skipped"):
            return -math.inf, {"skip_reason": rec["skip_reason"]}
        mem = rec["memory"]["per_device_B"]
        meta = {"roofline": rec["roofline"], "mem_per_device_B": mem}
        if mem > self.hbm_bytes:
            return -math.inf, dict(meta, oom=True)
        return float(rec["roofline"]["throughput_tok_s"]), meta


class WallClockEvaluator(Evaluator):
    """Measured throughput of a step built from the configuration point.

    ``make_step(point) -> (step_fn, args, examples_per_step)``:
    the builder applies the point's backend parameters (Runtime knobs,
    microbatches, ...) and returns a jittable step plus its inputs.
    """

    def __init__(
        self,
        make_step: Callable[[Dict], Tuple[Callable, tuple, float]],
        *,
        warmup: int = 1,
        iters: int = 3,
    ):
        self.make_step = make_step
        self.warmup = warmup
        self.iters = iters

    def __call__(self, point: Dict) -> Tuple[float, dict]:
        step, args, examples = self.make_step(point)
        jitted = jax.jit(step)
        out = None
        for _ in range(self.warmup):
            out = jitted(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(self.iters):
            out = jitted(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / self.iters
        return examples / dt, {"step_seconds": dt}
