"""Wire protocol for the tuning fleet: framing + version negotiation.

Every message — worker RPC and tuning-service RPC alike — is a
**length-prefixed JSON object**: a 4-byte big-endian unsigned length
followed by that many bytes of UTF-8 JSON.  ``NaN`` and ``±Infinity``
use the Python ``json`` literals (both ends are this codebase), so
``-inf`` failure scores survive the round trip.

Version negotiation (v2)
------------------------

Version 1 had no negotiation: the client sent ``{"type": "hello",
"protocol": 1}`` and the worker rejected anything whose ``protocol``
was not exactly 1.  Version 2 keeps that hello *unchanged* and adds a
``max_protocol`` key next to it::

    {"type": "hello", "protocol": 1, "max_protocol": 2}

* a **v1 server** checks ``protocol == 1`` (true) and ignores keys it
  does not know — so a v2 client registers against a v1 worker and the
  session simply runs the v1 message set;
* a **v2 server** answers with the highest version both sides support
  (``min(client max_protocol, server ceiling)``) in its register/
  welcome reply, and the session speaks that version from then on.

``protocol`` in the hello therefore stays pinned at 1 forever — it is
the *floor* (and the compatibility statement), ``max_protocol`` is the
ceiling.  :func:`negotiate` implements the server side; clients read
the chosen version out of the reply's ``protocol`` field.

Version 2 message set (on top of v1's task/result/heartbeat/bye):

===================  ====================================================
``submit_job``       client -> service: a :class:`JobSpec` payload
``job_accepted``     service -> client: ``{"job_id": ...}``
``job_status``       client -> service: ``{"job_id": ...}``
``status``           service -> client: progress snapshot (state, evals,
                     best, best-so-far curve, rung stats, fleet health)
``list_jobs``        client -> service
``jobs``             service -> client: one summary row per job
``cancel_job``       client -> service: ``{"job_id": ...}``
``leaving``          worker -> tuner: clean deregistration — the pool
                     stops dispatching here, drains this worker's
                     in-flight results, then ends the session with
                     ``bye`` (elastic fleets)
``error``            either direction: ``{"error": "..."}``
===================  ====================================================

A v2 ``task`` message may additionally carry a ``state`` field: the
opaque checkpoint-fork blob (a prior step's ``meta["fork_state"]``,
PBT lineages) the worker forwards to its objective as
``resume_state``.  The pool only routes stateful tasks to v2 workers;
a v1 worker never sees the field.

A v2 worker's ``register`` reply additionally carries a
``fingerprint`` object (``tundb.hardware_fingerprint()`` form) so the
pool can partition a mixed fleet by hardware; v1 workers simply omit
it and land in the synthetic "unknown" partition.  Both sides ignore
unknown keys, so every addition above is invisible to a v1 peer.

This module is deliberately stdlib-only (no jax, no numpy): worker
daemons and thin clients import it on hosts that have nothing else
installed.
"""
from __future__ import annotations

import json
import socket
import struct
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

#: the original worker-RPC protocol: hello/register/task/result/
#: heartbeat/bye, no negotiation.
PROTOCOL_V1 = 1
#: adds version negotiation (``max_protocol``), register-time error
#: reporting, and the tuning-service job message set.
PROTOCOL_V2 = 2
SUPPORTED_PROTOCOLS = (PROTOCOL_V1, PROTOCOL_V2)

_HEADER = struct.Struct(">I")
# corruption guard, not a capacity plan: a frame is one point/result
MAX_FRAME_BYTES = 64 << 20
DEFAULT_HEARTBEAT_S = 2.0


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def send_msg(sock: socket.socket, obj: dict) -> None:
    """Send one length-prefixed JSON message."""
    data = json.dumps(obj, allow_nan=True).encode("utf-8")
    sock.sendall(_HEADER.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed the connection mid-frame")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket) -> dict:
    """Receive one length-prefixed JSON message (blocking)."""
    (length,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if length > MAX_FRAME_BYTES:
        raise ValueError(
            f"frame of {length} bytes exceeds the {MAX_FRAME_BYTES}-byte "
            "protocol limit (corrupt stream?)")
    msg = json.loads(_recv_exact(sock, length).decode("utf-8"))
    if not isinstance(msg, dict):
        raise ValueError(f"protocol messages are JSON objects, got {type(msg)}")
    return msg


def parse_address(addr: str) -> Tuple[str, int]:
    """``"host:port"`` -> ``(host, port)`` with a helpful error."""
    host, sep, port = addr.rpartition(":")
    if not sep or not host:
        raise ValueError(f"worker address {addr!r} is not host:port")
    return host, int(port)


# ---------------------------------------------------------------------------
# version negotiation
# ---------------------------------------------------------------------------

def hello(max_protocol: int = PROTOCOL_V2) -> dict:
    """The client-side hello.  ``protocol`` is pinned to 1 — the floor a
    v1 server insists on — and ``max_protocol`` advertises the ceiling."""
    msg = {"type": "hello", "protocol": PROTOCOL_V1}
    if max_protocol > PROTOCOL_V1:
        msg["max_protocol"] = int(max_protocol)
    return msg


def negotiate(hello_msg: dict, ceiling: int = PROTOCOL_V2) -> Optional[int]:
    """Server side: the version this session will speak, or ``None`` if
    the hello is not compatible.

    ``ceiling`` caps what the server offers (tests pin it to 1 to
    exercise the v1-server path).
    """
    if hello_msg.get("type") != "hello":
        return None
    base = hello_msg.get("protocol")
    if base != PROTOCOL_V1:  # the floor never moves: v1 compat statement
        return None
    peer_max = hello_msg.get("max_protocol", base)
    try:
        chosen = min(int(peer_max), int(ceiling))
    except (TypeError, ValueError):
        return None
    chosen = max(chosen, PROTOCOL_V1)
    return chosen if chosen in SUPPORTED_PROTOCOLS else PROTOCOL_V1


# ---------------------------------------------------------------------------
# job specification (service wire/checkpoint schema)
# ---------------------------------------------------------------------------

@dataclass
class JobSpec:
    """What a client submits: a search space + tuner configuration.

    ``space`` is ``SearchSpace.to_dicts()`` form; ``config`` is
    ``TunerConfig.to_dict()`` form (validated server-side by
    ``TunerConfig.from_dict``, so unknown keys come back as a precise
    ``error`` reply, not a silent ignore).  ``objective`` optionally
    names a ``module:factory()`` spec for services running local
    measurement — services driving a remote fleet ignore it (workers
    own their objectives).
    """
    space: List[dict]
    config: dict = field(default_factory=dict)
    name: str = ""
    objective: Optional[str] = None

    def to_dict(self) -> dict:
        return {"space": [dict(d) for d in self.space],
                "config": dict(self.config), "name": self.name,
                "objective": self.objective}

    @classmethod
    def from_dict(cls, d: dict) -> "JobSpec":
        unknown = set(d) - {"space", "config", "name", "objective"}
        if unknown:
            raise ValueError(
                f"unknown JobSpec key(s): {sorted(unknown)} "
                "(known: space, config, name, objective)")
        space = d.get("space")
        if not isinstance(space, list) or not space:
            raise ValueError("JobSpec needs a non-empty 'space' list "
                             "(SearchSpace.to_dicts() form)")
        return cls(space=[dict(x) for x in space],
                   config=dict(d.get("config") or {}),
                   name=str(d.get("name") or ""),
                   objective=d.get("objective"))
