"""Algorithm-engine interface (paper Fig. 4: algorithmic engines behind a
selection switch, all sharing the same history / system-under-test path)."""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.history import History
from repro.core.space import SearchSpace


class Engine:
    name = "base"

    def __init__(self, space: SearchSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)

    def suggest(self, history: History) -> Dict:
        raise NotImplementedError

    def observe(self, point: Dict, value: float) -> None:  # optional state
        pass

    # -- helpers -------------------------------------------------------------
    def _unseen(self, history: History, point: Dict, tries: int = 64) -> Dict:
        """Nudge a suggestion off already-evaluated grid points."""
        cand = point
        for radius in [1, 1, 2, 2, 3, 4] * (tries // 6 + 1):
            if not history.seen(cand):
                return cand
            cand = self.space.perturb(self.rng, cand, radius=radius)
        # grid may be nearly exhausted: fall back to random
        for _ in range(tries):
            cand = self.space.sample(self.rng, 1)[0]
            if not history.seen(cand):
                return cand
        return cand
