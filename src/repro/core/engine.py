"""Algorithm-engine interface (paper Fig. 4: algorithmic engines behind a
selection switch, all sharing the same history / system-under-test path).

Batched ask/tell contract
-------------------------

Engines expose two methods:

* ``ask(n, history) -> list[point]`` — propose up to ``n`` deduplicated
  candidate points.  The batch excludes points already evaluated
  (``history.seen``) and points currently in flight
  (``history.pending``), so a parallel executor can measure the whole
  batch concurrently without wasted repeats.
* ``tell(points, values)`` — report measured objective values back, in
  the same order the points were proposed.  The default implementation
  forwards each pair to ``observe`` (the single-point state update),
  which is what most engines need; engines with speculative batches
  (Nelder-Mead) override it.

``ask(1, ...)`` is guaranteed to consume the engine RNG exactly like the
historical single-point ``suggest`` did, so a sequential driver
(``parallelism=1``) reproduces the pre-batching suggestion trace
bit-for-bit for the same seed.  ``suggest`` remains as a thin
compatibility wrapper over ``ask(1, ...)``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.history import History
from repro.core.space import SearchSpace


class Engine:
    name = "base"

    def __init__(self, space: SearchSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)

    # -- batched contract -----------------------------------------------------
    def ask(self, n: int, history: History) -> List[Dict]:
        """Propose up to ``n`` deduplicated candidate points."""
        raise NotImplementedError

    def tell(self, points: Sequence[Dict], values: Sequence[float]) -> None:
        """Report objective values for a previously asked batch (in order)."""
        for p, v in zip(points, values):
            self.observe(p, v)

    # -- single-point compatibility shims ------------------------------------
    def suggest(self, history: History) -> Dict:
        """Deprecated single-point API; equivalent to ``ask(1, ...)[0]``."""
        return self.ask(1, history)[0]

    def observe(self, point: Dict, value: float) -> None:  # optional state
        pass

    # -- helpers -------------------------------------------------------------
    def _unseen(self, history: History, point: Dict, tries: int = 64,
                exclude: Optional[Set[Tuple]] = None) -> Dict:
        """Nudge a suggestion off already-evaluated / in-flight grid points.

        ``exclude`` carries the keys of points already emitted in the
        current batch so one ``ask`` never proposes duplicates.
        """
        exclude = exclude or set()

        def taken(p: Dict) -> bool:
            return (history.seen(p) or history.pending(p)
                    or self.space.key(p) in exclude)

        cand = point
        for radius in [1, 1, 2, 2, 3, 4] * (tries // 6 + 1):
            if not taken(cand):
                return cand
            cand = self.space.perturb(self.rng, cand, radius=radius)
        # grid may be nearly exhausted: fall back to random
        for _ in range(tries):
            cand = self.space.sample(self.rng, 1)[0]
            if not taken(cand):
                return cand
        return cand
