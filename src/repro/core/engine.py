"""Algorithm-engine interface (paper Fig. 4: algorithmic engines behind a
selection switch, all sharing the same history / system-under-test path).

Batched ask/tell contract
-------------------------

Engines expose two methods:

* ``ask(n, history) -> list[point]`` — propose up to ``n`` deduplicated
  candidate points.  The batch excludes points already evaluated
  (``history.seen``) and points currently in flight
  (``history.pending``), so a parallel executor can measure the whole
  batch concurrently without wasted repeats.
* ``tell(observations)`` — report measured results back as
  :class:`~repro.core.observation.Observation` records (point, value,
  cost_seconds, fidelity, rung, meta — one object per completed
  measurement, the same schema the tuning service and the checkpoint
  snapshots serialize).  Under the completion-driven tuner loop,
  ``tell`` arrives *incrementally and in completion order*: typically
  one observation at a time, the moment its measurement finishes, which
  may not be the order the points were asked.  Engines must therefore
  tolerate partial and reordered feedback; the default implementation
  (``_tell``) forwards each observation to ``observe`` (the
  single-point state update), which is order-free and what most engines
  need, while engines with speculative batches (Nelder-Mead) buffer
  results and reconcile them against their state machine.  Each
  observation's ``cost_seconds`` is accumulated by the base class so
  engines can become wall-clock-aware (see ``mean_cost_seconds``).

  The historical keyword sprawl — ``tell(points, values, costs=...,
  fidelities=...)`` — remains as a deprecation shim: calls that pass
  ``values`` are converted to observations and emit a
  ``DeprecationWarning``.  The conversion is exact (costs default to
  0.0, fidelities to 1.0, like the old signature), so existing callers
  keep their behavior bit-for-bit.

``ask(1, ...)`` is guaranteed to consume the engine RNG exactly like the
historical single-point ``suggest`` did, so a sequential driver
(``parallelism=1``) reproduces the pre-batching suggestion trace
bit-for-bit for the same seed.  ``suggest`` remains as a thin
compatibility wrapper over ``ask(1, ...)``.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.history import History
from repro.core.observation import Observation
from repro.core.space import SearchSpace


class Engine:
    name = "base"

    #: whether the tuner's transfer pre-filter may over-ask this engine and
    #: measure only the top-ranked fraction of the batch.  Safe for engines
    #: whose asks are independent suggestions (random/GA/BO); engines whose
    #: asks consume irreplaceable state must opt out — Nelder-Mead's
    #: speculative batches require every asked point to eventually be told,
    #: and Exhaustive's one-shot grid iterator never re-proposes a point a
    #: filter dropped.
    prefilter_safe = True

    def __init__(self, space: SearchSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self._cost_log: List[float] = []  # measured seconds per told result
        #: set by engines whose ``ask`` pads the tail of an exhausted
        #: candidate pool with unranked random fills (warm-started BO):
        #: the count of *ranked* candidates at the head of the most
        #: recent batch, or ``None`` when the whole batch is ranked (or
        #: the engine makes no such distinction).  The tuner's transfer
        #: pre-filter re-ranks only the ranked head, so a random fill
        #: can never displace a candidate the engine actually ranked.
        self.last_ask_ranked: Optional[int] = None
        #: fraction of the wall-clock budget still left (None = no budget);
        #: updated by the tuner via ``note_budget`` so cost-aware engines can
        #: sharpen their cheap-probe preference as the deadline approaches
        self.budget_fraction_remaining: Optional[float] = None

    # -- batched contract -----------------------------------------------------
    def ask(self, n: int, history: History) -> List[Dict]:
        """Propose up to ``n`` deduplicated candidate points."""
        raise NotImplementedError

    def tell(self, observations: Sequence[Observation],
             values: Optional[Sequence[float]] = None,
             costs: Optional[Sequence[float]] = None,
             fidelities: Optional[Sequence[float]] = None) -> None:
        """Report completed measurements for previously asked points.

        ``observations`` is a sequence of :class:`Observation` records.
        May be called once per completed evaluation (completion order)
        or once per batch; both must leave the engine in the same state.

        ``Observation.fidelity`` (multi-fidelity tuning) marks values
        that came from partial measurements (< 1.0 = cheaper, noisier).
        The base implementation ignores it — engines whose state
        machines want exact values (GA's population, NMS's simplex)
        treat partial values as the ASHA literature does: good enough to
        rank on.  BayesOpt instead reads fidelities straight from the
        history as a surrogate input feature, so its GP never mistakes a
        partial value for an exact one.

        Engines customize by overriding :meth:`_tell`, never ``tell``
        itself: ``tell`` owns the legacy-signature shim (``tell(points,
        values, costs=..., fidelities=...)``, deprecated) and the cost
        accounting, so every engine sees one normalized observation
        stream.
        """
        obs = self._coerce_observations(observations, values, costs,
                                        fidelities)
        self._cost_log.extend(o.cost_seconds for o in obs)
        self._tell(obs)

    def _tell(self, observations: Sequence[Observation]) -> None:
        """Engine-specific state update; default forwards to ``observe``."""
        for o in observations:
            self.observe(o.point, o.value)

    @staticmethod
    def _coerce_observations(observations, values, costs,
                             fidelities) -> List[Observation]:
        if values is not None:  # legacy tell(points, values, ...) signature
            warnings.warn(
                "Engine.tell(points, values, costs=..., fidelities=...) is "
                "deprecated; pass a sequence of repro.core.Observation",
                DeprecationWarning, stacklevel=3)
            points = observations
            return [
                Observation(
                    point=dict(p), value=float(v),
                    cost_seconds=(0.0 if costs is None else float(costs[i])),
                    fidelity=(1.0 if fidelities is None
                              else float(fidelities[i])))
                for i, (p, v) in enumerate(zip(points, values))
            ]
        out = list(observations)
        for o in out:
            if not isinstance(o, Observation):
                raise TypeError(
                    f"tell() takes Observation records, got {type(o).__name__}"
                    " (legacy point/value sequences must pass values= too)")
        return out

    @property
    def mean_cost_seconds(self) -> float:
        """Mean measured evaluation cost — the wall-clock-awareness hook."""
        paid = [c for c in self._cost_log if c > 0]
        return sum(paid) / len(paid) if paid else 0.0

    def note_budget(self, fraction_remaining: Optional[float]) -> None:
        """Tuner hook: report how much of the wall-clock budget is left.

        ``None`` clears budget pressure (no wall-clock budget configured).
        Engines are free to ignore this; BayesOpt's cost-aware acquisition
        uses it to ramp EI-per-second weighting in near the deadline.
        """
        self.budget_fraction_remaining = fraction_remaining

    # -- single-point compatibility shims ------------------------------------
    def suggest(self, history: History) -> Dict:
        """Deprecated single-point API; equivalent to ``ask(1, ...)[0]``."""
        return self.ask(1, history)[0]

    def observe(self, point: Dict, value: float) -> None:  # optional state
        pass

    # -- helpers -------------------------------------------------------------
    def _unseen(self, history: History, point: Dict, tries: int = 64,
                exclude: Optional[Set[Tuple]] = None) -> Dict:
        """Nudge a suggestion off already-evaluated / in-flight grid points.

        ``exclude`` carries the keys of points already emitted in the
        current batch so one ``ask`` never proposes duplicates.
        """
        exclude = exclude or set()

        def taken(p: Dict) -> bool:
            return (history.seen(p) or history.pending(p)
                    or self.space.key(p) in exclude)

        cand = point
        for radius in [1, 1, 2, 2, 3, 4] * (tries // 6 + 1):
            if not taken(cand):
                return cand
            cand = self.space.perturb(self.rng, cand, radius=radius)
        # grid may be nearly exhausted: fall back to random
        for _ in range(tries):
            cand = self.space.sample(self.rng, 1)[0]
            if not taken(cand):
                return cand
        return cand
