"""Algorithm-engine interface (paper Fig. 4: algorithmic engines behind a
selection switch, all sharing the same history / system-under-test path).

Batched ask/tell contract
-------------------------

Engines expose two methods:

* ``ask(n, history) -> list[point]`` — propose up to ``n`` deduplicated
  candidate points.  The batch excludes points already evaluated
  (``history.seen``) and points currently in flight
  (``history.pending``), so a parallel executor can measure the whole
  batch concurrently without wasted repeats.
* ``tell(points, values, costs=None)`` — report measured objective
  values back.  Under the completion-driven tuner loop, ``tell`` arrives
  *incrementally and in completion order*: typically one result at a
  time, the moment its measurement finishes, which may not be the order
  the points were asked.  Engines must therefore tolerate partial and
  reordered feedback; the default implementation forwards each pair to
  ``observe`` (the single-point state update), which is order-free and
  what most engines need, while engines with speculative batches
  (Nelder-Mead) buffer results and reconcile them against their state
  machine.  ``costs`` carries the measured ``cost_seconds`` of each
  evaluation so engines can become wall-clock-aware (the base class
  accumulates them; see ``mean_cost_seconds``).

``ask(1, ...)`` is guaranteed to consume the engine RNG exactly like the
historical single-point ``suggest`` did, so a sequential driver
(``parallelism=1``) reproduces the pre-batching suggestion trace
bit-for-bit for the same seed.  ``suggest`` remains as a thin
compatibility wrapper over ``ask(1, ...)``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.history import History
from repro.core.space import SearchSpace


class Engine:
    name = "base"

    def __init__(self, space: SearchSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self._cost_log: List[float] = []  # measured seconds per told result
        #: fraction of the wall-clock budget still left (None = no budget);
        #: updated by the tuner via ``note_budget`` so cost-aware engines can
        #: sharpen their cheap-probe preference as the deadline approaches
        self.budget_fraction_remaining: Optional[float] = None

    # -- batched contract -----------------------------------------------------
    def ask(self, n: int, history: History) -> List[Dict]:
        """Propose up to ``n`` deduplicated candidate points."""
        raise NotImplementedError

    def tell(self, points: Sequence[Dict], values: Sequence[float],
             costs: Optional[Sequence[float]] = None,
             fidelities: Optional[Sequence[float]] = None) -> None:
        """Report objective values for previously asked points.

        May be called once per completed evaluation (completion order)
        or once per batch; both must leave the engine in the same state.

        ``fidelities`` (multi-fidelity tuning) marks which values came
        from partial measurements (< 1.0 = cheaper, noisier).  The base
        implementation ignores it — engines whose state machines want
        exact values (GA's population, NMS's simplex) treat partial
        values as the ASHA literature does: good enough to rank on.
        BayesOpt instead reads fidelities straight from the history as a
        surrogate input feature, so its GP never mistakes a partial
        value for an exact one.
        """
        self._record_costs(costs, len(points))
        for p, v in zip(points, values):
            self.observe(p, v)

    def _record_costs(self, costs: Optional[Sequence[float]], n: int) -> None:
        self._cost_log.extend([0.0] * n if costs is None else costs)

    @property
    def mean_cost_seconds(self) -> float:
        """Mean measured evaluation cost — the wall-clock-awareness hook."""
        paid = [c for c in self._cost_log if c > 0]
        return sum(paid) / len(paid) if paid else 0.0

    def note_budget(self, fraction_remaining: Optional[float]) -> None:
        """Tuner hook: report how much of the wall-clock budget is left.

        ``None`` clears budget pressure (no wall-clock budget configured).
        Engines are free to ignore this; BayesOpt's cost-aware acquisition
        uses it to ramp EI-per-second weighting in near the deadline.
        """
        self.budget_fraction_remaining = fraction_remaining

    # -- single-point compatibility shims ------------------------------------
    def suggest(self, history: History) -> Dict:
        """Deprecated single-point API; equivalent to ``ask(1, ...)[0]``."""
        return self.ask(1, history)[0]

    def observe(self, point: Dict, value: float) -> None:  # optional state
        pass

    # -- helpers -------------------------------------------------------------
    def _unseen(self, history: History, point: Dict, tries: int = 64,
                exclude: Optional[Set[Tuple]] = None) -> Dict:
        """Nudge a suggestion off already-evaluated / in-flight grid points.

        ``exclude`` carries the keys of points already emitted in the
        current batch so one ``ask`` never proposes duplicates.
        """
        exclude = exclude or set()

        def taken(p: Dict) -> bool:
            return (history.seen(p) or history.pending(p)
                    or self.space.key(p) in exclude)

        cand = point
        for radius in [1, 1, 2, 2, 3, 4] * (tries // 6 + 1):
            if not taken(cand):
                return cand
            cand = self.space.perturb(self.rng, cand, radius=radius)
        # grid may be nearly exhausted: fall back to random
        for _ in range(tries):
            cand = self.space.sample(self.rng, 1)[0]
            if not taken(cand):
                return cand
        return cand
