"""Genetic-algorithm engine (paper §2.2).

Faithful to the paper's description: at each iteration the history is
reordered by a fitness function, the inputs of the two fittest pairs are
selected as parents, a child is produced by *crossover* (each component
copied from one of the two parents) and *mutation* (components flipped to
purely random values with small probability).

``ask(n, ...)`` emits a *generation*: n distinct children bred from the
current two fittest parents, which is the natural unit of parallel
measurement for a GA.

Under the completion-driven tuner loop the GA becomes *steady-state*:
results are told (and land in the shared history) one at a time in
completion order, and each replacement child is bred from the two
fittest individuals *at that moment* — there is no generation barrier,
so a strong early-finishing individual starts parenting immediately.
The engine itself stays stateless between calls: parent selection reads
the history, which is exactly what makes out-of-order insertion safe.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.engine import Engine
from repro.core.history import History
from repro.core.space import SearchSpace


class GeneticAlgorithm(Engine):
    name = "ga"

    def __init__(
        self,
        space: SearchSpace,
        seed: int = 0,
        n_init: int = 6,
        mutation_rate: float = 0.15,
        tournament: int = 0,  # 0 => paper's plain two-fittest selection
    ):
        super().__init__(space, seed)
        self.n_init = min(n_init, max(2, space.grid_size() // 2))
        self.mutation_rate = mutation_rate
        self.tournament = tournament
        self._init_points = None

    def _select_parents(self, history: History):
        order = sorted(
            (e for e in history.evals if np.isfinite(e.value)),
            key=lambda e: -e.value,
        )
        if len(order) < 2:
            return None
        if self.tournament:
            def pick():
                return max(
                    self.rng.choice(order,
                                    size=min(self.tournament, len(order)),
                                    replace=False),
                    key=lambda e: e.value,
                )
            return pick().point, pick().point
        return order[0].point, order[1].point

    def _breed(self, pa: Dict, pb: Dict) -> Dict:
        child = {}
        for d in self.space.dims:
            # crossover: copy the component from one of the two parents
            child[d.name] = pa[d.name] if self.rng.random() < 0.5 else pb[d.name]
            # mutation: occasionally a purely random value
            if self.rng.random() < self.mutation_rate:
                child[d.name] = d.values[self.rng.integers(len(d.values))]
        return child

    def ask(self, n: int, history: History) -> List[Dict]:
        if self._init_points is None:
            self._init_points = self.space.sample_lhs(self.rng, self.n_init)
        batch: List[Dict] = []
        keys = set()
        while len(batch) < n:
            idx = len(history) + history.n_pending() + len(batch)
            if idx < self.n_init:
                p = self._unseen(history, self._init_points[idx], exclude=keys)
            else:
                parents = self._select_parents(history)
                if parents is None:
                    p = self._unseen(history, self.space.sample(self.rng, 1)[0],
                                     exclude=keys)
                else:
                    p = self._unseen(history, self._breed(*parents),
                                     exclude=keys)
            keys.add(self.space.key(p))
            batch.append(p)
        return batch
