"""The `Observation` record: one completed measurement, everywhere.

Before this module, a completed measurement travelled as parallel
positional sequences — ``Engine.tell(points, values, costs=...,
fidelities=...)``, mirrored by ``History.add_batch`` and the executor's
completion plumbing — which meant every new per-measurement field
(fidelity, rung, meta) widened *four* signatures and silently defaulted
everywhere it was forgotten.  :class:`Observation` collapses the sprawl
into a single dataclass that is also the canonical **wire format**: the
tuning service's ``submit_job``/``job_status`` messages and the job
checkpoint snapshots serialize observations with :meth:`to_dict` /
:meth:`from_dict`, so what an engine is told, what a history records,
and what crosses a socket are one schema.

This module is dependency-light on purpose (stdlib only): the remote
protocol layer imports it without pulling in numpy/jax.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class Observation:
    """One completed evaluation reported back to an engine / history.

    ``point``         the measured configuration (dict of parameter values)
    ``value``         objective (throughput-like; higher is better;
                      ``-inf`` marks a failed configuration)
    ``cost_seconds``  measured cost of producing the value (0.0 = unknown
                      or free, e.g. a memoized repeat)
    ``fidelity``      fraction of a full measurement the value came from
                      (1.0 = exact/full; < 1.0 = cheaper, noisier)
    ``rung``          scheduler coordinate the measurement ran at — the
                      successive-halving rung for ASHA, the *global*
                      (bracket-offset) rung for HyperBand, the step
                      index for PBT (``None`` = outside any scheduler)
    ``lineage``       trial-ancestry tag for scheduler provenance —
                      HyperBand's bracket (``b<idx>``), PBT's member
                      lineage (``m<k>``); ``None`` = no lineage.  The
                      resume path routes ``replay`` by it, and PBT's
                      checkpoint-fork steps are memo-keyed by it
    ``meta``          JSON-serializable annotations from the evaluator
    """

    point: Dict
    value: float
    cost_seconds: float = 0.0
    fidelity: float = 1.0
    rung: Optional[int] = None
    lineage: Optional[str] = None
    meta: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        """Wire/checkpoint form (plain JSON-serializable dict)."""
        return {
            "point": dict(self.point), "value": self.value,
            "cost_seconds": self.cost_seconds, "fidelity": self.fidelity,
            "rung": self.rung, "lineage": self.lineage,
            "meta": dict(self.meta),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Observation":
        return cls(
            point=dict(d["point"]), value=float(d["value"]),
            cost_seconds=float(d.get("cost_seconds", 0.0)),
            fidelity=float(d.get("fidelity", 1.0)),
            rung=d.get("rung"),
            lineage=d.get("lineage"),
            meta=dict(d.get("meta") or {}),
        )
