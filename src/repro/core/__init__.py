"""The paper's primary contribution: gradient-free auto-tuning of backend
parameters for training/inference throughput — BO (GP + SMSego), GA, and
Nelder-Mead simplex behind a common engine interface (paper Fig. 4).

Engines speak the ask/tell contract (``engine.ask(n, history)`` ->
deduplicated candidate batch; ``engine.tell(observations)`` feeds
:class:`Observation` records back, incrementally and in completion
order) and the
:class:`Tuner` drives them through a completion-driven scheduler over
the parallel evaluation executor (``repro.tuning.executor``) under an
iteration budget, a wall-clock budget, or both — with an optional
disk-backed memo cache so repeated runs re-evaluate nothing.
``parallelism=1`` reproduces the paper's sequential
one-point-per-iteration harness bit-for-bit.

BO runs a compile-once GP surrogate (``repro.core.gp``): bucketed/padded
jit shapes with validity masks, warm-started hyperparameter refits, and
a fused jitted acquisition — per-completion suggestion refresh costs
milliseconds, never an XLA recompile.  ``TunerConfig(cost_aware=True)``
switches BO to EI-per-second, trading improvement against a
per-candidate predicted measurement cost and sharpening the preference
for cheap probes as ``wall_clock_budget`` nears exhaustion."""
from repro.core.bayesopt import BayesOpt, TransferPrior
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.gp import GaussianProcess
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.observation import Observation
from repro.core.random_search import RandomSearch
from repro.core.space import CatDim, IntDim, SearchSpace
from repro.core.tuner import (ENGINES, ExecutorConfig, HyperBandConfig,
                              MultiFidelityConfig, PBTConfig, TransferConfig,
                              Tuner, TunerConfig)

__all__ = [
    "BayesOpt", "CatDim", "ENGINES", "Engine", "ExecutorConfig",
    "Exhaustive", "GaussianProcess", "GeneticAlgorithm", "History",
    "HyperBandConfig", "IntDim", "MultiFidelityConfig", "NelderMead",
    "Observation", "PBTConfig", "RandomSearch", "SearchSpace",
    "TransferConfig", "TransferPrior", "Tuner", "TunerConfig",
]
