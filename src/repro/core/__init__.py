"""The paper's primary contribution: gradient-free auto-tuning of backend
parameters for training/inference throughput — BO (GP + SMSego), GA, and
Nelder-Mead simplex behind a common engine interface (paper Fig. 4)."""
from repro.core.bayesopt import BayesOpt
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.gp import GaussianProcess
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.random_search import RandomSearch
from repro.core.space import CatDim, IntDim, SearchSpace
from repro.core.tuner import ENGINES, Tuner, TunerConfig

__all__ = [
    "BayesOpt", "CatDim", "ENGINES", "Engine", "Exhaustive",
    "GaussianProcess", "GeneticAlgorithm", "History", "IntDim", "NelderMead",
    "RandomSearch", "SearchSpace", "Tuner", "TunerConfig",
]
