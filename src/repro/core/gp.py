"""Gaussian-process surrogate (paper §2.2) — pure JAX.

ARD RBF / Matérn-5/2 kernels; hyperparameters (log-lengthscales, log
signal variance, log noise) fit by maximizing the log marginal likelihood
with Adam on ``jax.grad`` (the GP itself is white-box — the *objective* is
the black box).  Cholesky-based posterior, y standardized internally.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

_JITTER = 1e-5


def _sqdist(X1: jnp.ndarray, X2: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    a = X1 / ls
    b = X2 / ls
    return (
        jnp.sum(a * a, -1)[:, None]
        + jnp.sum(b * b, -1)[None, :]
        - 2.0 * a @ b.T
    ).clip(0.0)


def kernel_fn(kind: str, X1, X2, ls, sigma2):
    d2 = _sqdist(X1, X2, ls)
    if kind == "rbf":
        return sigma2 * jnp.exp(-0.5 * d2)
    if kind == "matern52":
        d = jnp.sqrt(d2 + 1e-12)
        s = jnp.sqrt(5.0) * d
        return sigma2 * (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(kind)


@partial(jax.jit, static_argnames=("kind",))
def _neg_mll(params: Dict, X, y, kind: str):
    ls = jnp.exp(params["log_ls"])
    sigma2 = jnp.exp(params["log_sigma2"])
    noise = jnp.exp(params["log_noise"]) + _JITTER
    n = X.shape[0]
    K = kernel_fn(kind, X, X, ls, sigma2) + noise * jnp.eye(n)
    Lc = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((Lc, True), y)
    mll = (
        -0.5 * y @ alpha
        - jnp.sum(jnp.log(jnp.diagonal(Lc)))
        - 0.5 * n * jnp.log(2 * jnp.pi)
    )
    return -mll


@partial(jax.jit, static_argnames=("kind", "steps"))
def _fit(params0: Dict, X, y, kind: str, steps: int, lr: float):
    grad = jax.grad(_neg_mll)

    def body(carry, _):
        params, m, v, t = carry
        g = grad(params, X, y, kind)
        t = t + 1
        m = jax.tree_util.tree_map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
        v = jax.tree_util.tree_map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_, v, g)
        mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - 0.999 ** t), v)
        params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + 1e-8), params, mh, vh
        )
        # keep hyperparameters in a sane box
        params = {
            "log_ls": jnp.clip(params["log_ls"], np.log(1e-2), np.log(1e2)),
            "log_sigma2": jnp.clip(params["log_sigma2"], np.log(1e-3), np.log(1e3)),
            "log_noise": jnp.clip(params["log_noise"], np.log(1e-4), np.log(1.0)),
        }
        return (params, m, v, t), None

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params0)
    (params, _, _, _), _ = jax.lax.scan(
        body, (params0, zeros, zeros, jnp.zeros((), jnp.int32)), None, length=steps
    )
    return params


@partial(jax.jit, static_argnames=("kind",))
def _posterior(params: Dict, X, y, Xs, kind: str):
    ls = jnp.exp(params["log_ls"])
    sigma2 = jnp.exp(params["log_sigma2"])
    noise = jnp.exp(params["log_noise"]) + _JITTER
    n = X.shape[0]
    K = kernel_fn(kind, X, X, ls, sigma2) + noise * jnp.eye(n)
    Lc = jnp.linalg.cholesky(K)
    Ks = kernel_fn(kind, X, Xs, ls, sigma2)  # (n, m)
    alpha = jax.scipy.linalg.cho_solve((Lc, True), y)
    mu = Ks.T @ alpha
    v = jax.scipy.linalg.solve_triangular(Lc, Ks, lower=True)
    var = sigma2 - jnp.sum(v * v, axis=0)
    return mu, jnp.clip(var, 1e-12)


@dataclass
class GPResult:
    mu: np.ndarray
    sigma: np.ndarray


class GaussianProcess:
    """Fit on (X in [0,1]^d, y); query posterior at candidate points."""

    def __init__(self, kind: str = "matern52", fit_steps: int = 120, lr: float = 0.05):
        self.kind = kind
        self.fit_steps = fit_steps
        self.lr = lr
        self._params = None
        self._X = None
        self._y = None
        self._y_mean = 0.0
        self._y_std = 1.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GaussianProcess":
        X = jnp.asarray(X, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
        yn = np.asarray(y, np.float64)
        self._y_mean = float(yn.mean())
        self._y_std = float(yn.std() + 1e-9)
        y_std = jnp.asarray((yn - self._y_mean) / self._y_std, X.dtype)
        d = X.shape[1]
        params0 = {
            "log_ls": jnp.full((d,), np.log(0.3), X.dtype),
            "log_sigma2": jnp.asarray(0.0, X.dtype),
            "log_noise": jnp.asarray(np.log(1e-3), X.dtype),
        }
        fitted = _fit(params0, X, y_std, self.kind, self.fit_steps, self.lr)
        # fp32 robustness: if the fitted hyperparameters make the Cholesky
        # blow up (near-singular K), fall back to safe defaults with a
        # larger noise floor.
        nll = _neg_mll(fitted, X, y_std, self.kind)
        if not bool(jnp.isfinite(nll)):
            fitted = {
                "log_ls": jnp.full_like(params0["log_ls"], np.log(0.3)),
                "log_sigma2": jnp.zeros_like(params0["log_sigma2"]),
                "log_noise": jnp.full_like(params0["log_noise"], np.log(1e-2)),
            }
        self._params = fitted
        self._X, self._y = X, y_std
        return self

    def posterior(self, Xs: np.ndarray) -> GPResult:
        assert self._params is not None, "fit first"
        mu, var = _posterior(
            self._params, self._X, self._y, jnp.asarray(Xs, self._X.dtype), self.kind
        )
        mu, var = np.asarray(mu), np.asarray(var)
        if not np.isfinite(mu).all():  # last-resort refit with big noise
            safe = dict(self._params)
            safe["log_noise"] = jnp.full_like(self._params["log_noise"],
                                              np.log(1e-1))
            mu, var = _posterior(safe, self._X, self._y,
                                 jnp.asarray(Xs, self._X.dtype), self.kind)
            mu, var = np.asarray(mu), np.asarray(var)
        mu = np.nan_to_num(mu, nan=0.0) * self._y_std + self._y_mean
        sigma = np.sqrt(np.clip(np.nan_to_num(var, nan=1.0), 1e-12, None)) * self._y_std
        return GPResult(mu, sigma)

    @property
    def lengthscales(self) -> np.ndarray:
        return np.exp(np.asarray(self._params["log_ls"]))
