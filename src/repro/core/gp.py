"""Gaussian-process surrogate (paper §2.2) — pure JAX, compile-once.

ARD RBF / Matérn-5/2 kernels; hyperparameters (log-lengthscales, log
signal variance, log noise) fit by maximizing the log marginal likelihood
with Adam on ``jax.grad`` (the GP itself is white-box — the *objective* is
the black box).  Cholesky-based posterior, y standardized internally.

Compile-once shape discipline
-----------------------------

Under the completion-driven tuner loop the training set grows by one row
per completed measurement, and a naive jit over ``(n, d)`` arrays pays a
fresh XLA compile for every new ``n`` (~0.5–1 s per ask — the ROADMAP
"BO suggestion overhead" item).  Instead, every array entering a jitted
function here is padded to a power-of-two **bucket** (minimum
:data:`MIN_BUCKET`) with an explicit validity mask threaded through
``_neg_mll`` / ``_fit`` / ``_posterior``:

* live rows come first (the mask is a prefix mask), padded rows carry
  zeros;
* the masked Gram matrix gives padded rows a unit diagonal and zero
  cross-covariance, so the Cholesky factor is block-diagonal — the live
  block is *exactly* the unpadded factor — and the MLL restricted to the
  live prefix is exact (padded rows contribute ``log 1 = 0`` and
  ``alpha = 0``);
* the candidate axis of the posterior/acquisition is bucketed the same
  way, with padded candidates pinned to ``-inf`` acquisition.

The jit cache therefore holds O(log n) entries per kernel kind instead
of O(n): once the bucket schedule is warm, history growth within a
bucket triggers **zero** new compiles (see :func:`jit_cache_entries`,
asserted by tests and the ``bench-smoke`` CI gate).

Warm starts: ``fit(X, y, params0=...)`` resumes Adam from a previous
fit's hyperparameters and runs the short ``warm_steps`` schedule (120
cold / 30 warm by default), so the per-completion refit costs a few
dozen cheap jitted steps instead of a full cold optimization.

``acquisition_rank`` fuses posterior + acquisition (EI / UCB / SMSego,
optionally cost-aware EI-per-second against a second cost GP) + ranking
into a single jitted call that returns sorted candidate indices — the
(n, m) covariance never round-trips to host.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_JITTER = 1e-5

#: smallest padded training-set / candidate-set size; buckets are
#: MIN_BUCKET * 2**k, so the jit cache stays O(log n)
MIN_BUCKET = 8


def bucket_size(n: int, minimum: int = MIN_BUCKET) -> int:
    """Smallest power-of-two bucket (>= ``minimum``) holding ``n`` rows."""
    b = int(minimum)
    while b < n:
        b *= 2
    return b


def _pad_rows(a: np.ndarray, b: int) -> np.ndarray:
    """Zero-pad the leading axis of ``a`` to ``b`` rows (prefix-live)."""
    if a.shape[0] == b:
        return a
    pad = [(0, b - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad)


def _sqdist(X1: jnp.ndarray, X2: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    a = X1 / ls
    b = X2 / ls
    return (
        jnp.sum(a * a, -1)[:, None]
        + jnp.sum(b * b, -1)[None, :]
        - 2.0 * a @ b.T
    ).clip(0.0)


def kernel_fn(kind: str, X1, X2, ls, sigma2):
    d2 = _sqdist(X1, X2, ls)
    if kind == "rbf":
        return sigma2 * jnp.exp(-0.5 * d2)
    if kind == "matern52":
        d = jnp.sqrt(d2 + 1e-12)
        s = jnp.sqrt(5.0) * d
        return sigma2 * (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(kind)


def _masked_gram(kind: str, X, mask, ls, sigma2, noise):
    """Gram matrix exact on the live prefix, identity on padded rows.

    Padded rows get a unit diagonal and zero cross-covariance, so the
    Cholesky factor is block-diagonal with the live block identical to
    the unpadded factor.
    """
    K = kernel_fn(kind, X, X, ls, sigma2)
    m2 = mask[:, None] * mask[None, :]
    return K * m2 + jnp.diag(noise * mask + (1.0 - mask))


def _chol_alpha(params: Dict, X, y, mask, kind: str, noise_row=None):
    ls = jnp.exp(params["log_ls"])
    sigma2 = jnp.exp(params["log_sigma2"])
    noise = jnp.exp(params["log_noise"]) + _JITTER
    if noise_row is not None:
        # per-row observation-noise scale (>= 1), used by transfer warm
        # starts to down-weight prior-workload rows; ``None`` resolves at
        # trace time, so the no-transfer path compiles the identical jaxpr
        noise = noise * noise_row
    K = _masked_gram(kind, X, mask, ls, sigma2, noise)
    Lc = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((Lc, True), y * mask)
    return Lc, alpha, ls, sigma2


@partial(jax.jit, static_argnames=("kind",))
def _neg_mll(params: Dict, X, y, mask, kind: str, noise_row=None):
    n = jnp.sum(mask)
    Lc, alpha, _, _ = _chol_alpha(params, X, y, mask, kind, noise_row)
    mll = (
        -0.5 * (y * mask) @ alpha
        - jnp.sum(mask * jnp.log(jnp.diagonal(Lc)))
        - 0.5 * n * jnp.log(2 * jnp.pi)
    )
    return -mll


@partial(jax.jit, static_argnames=("kind", "steps"))
def _fit(params0: Dict, X, y, mask, kind: str, steps: int, lr: float,
         noise_row=None):
    grad = jax.grad(_neg_mll)

    def body(carry, _):
        params, m, v, t = carry
        g = grad(params, X, y, mask, kind, noise_row)
        t = t + 1
        m = jax.tree_util.tree_map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
        v = jax.tree_util.tree_map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_, v, g)
        mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - 0.999 ** t), v)
        params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + 1e-8), params, mh, vh
        )
        # keep hyperparameters in a sane box
        params = {
            "log_ls": jnp.clip(params["log_ls"], np.log(1e-2), np.log(1e2)),
            "log_sigma2": jnp.clip(params["log_sigma2"], np.log(1e-3), np.log(1e3)),
            "log_noise": jnp.clip(params["log_noise"], np.log(1e-4), np.log(1.0)),
        }
        return (params, m, v, t), None

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params0)
    (params, _, _, _), _ = jax.lax.scan(
        body, (params0, zeros, zeros, jnp.zeros((), jnp.int32)), None, length=steps
    )
    return params


def _posterior_core(params: Dict, X, y, mask, Xs, kind: str, noise_row=None):
    """Masked posterior on padded shapes; exact on the live prefix."""
    Lc, alpha, ls, sigma2 = _chol_alpha(params, X, y, mask, kind, noise_row)
    Ks = kernel_fn(kind, X, Xs, ls, sigma2) * mask[:, None]  # (n, m)
    mu = Ks.T @ alpha
    v = jax.scipy.linalg.solve_triangular(Lc, Ks, lower=True)
    var = sigma2 - jnp.sum(v * v, axis=0)
    return mu, jnp.clip(var, 1e-12)


_posterior = jax.jit(_posterior_core, static_argnames=("kind",))


@partial(jax.jit, static_argnames=("kind", "acquisition", "cost_aware"))
def _acq_rank(params: Dict, X, y, mask, Xs, cand_mask,
              y_mean, y_std, y_best, kappa, eps,
              cost_params: Dict, cost_y, cost_mean, cost_std,
              cost_alpha, mean_cost,
              kind: str, acquisition: str, cost_aware: bool,
              noise_row=None, cost_noise_row=None):
    """Fused posterior + acquisition + ranking on padded shapes.

    Returns ``(order, acq)``: candidate indices sorted by descending
    acquisition (stable, padded candidates last at ``-inf``) and the raw
    de-standardized acquisition values.  The (n, m) cross-covariance and
    the triangular solves stay on device.
    """
    mu_s, var_s = _posterior_core(params, X, y, mask, Xs, kind, noise_row)
    mu = mu_s * y_std + y_mean
    sigma = jnp.sqrt(var_s) * y_std
    if acquisition == "ucb":
        acq = mu + kappa * sigma
    elif acquisition == "ei":
        z = (mu - y_best) / jnp.maximum(sigma, 1e-12)
        cdf = 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
        pdf = jnp.exp(-0.5 * z * z) / jnp.sqrt(2 * jnp.pi)
        acq = (mu - y_best) * cdf + sigma * pdf
    elif acquisition == "smsego":
        # single-objective SMSego gain: how far the optimistic estimate
        # extends the best observation (epsilon-dominance guard keeps
        # pure-exploitation candidates from pinning the search)
        optimistic = mu + kappa * sigma
        gain = optimistic - (y_best + eps)
        acq = jnp.where(gain > 0, gain, gain * 1e-3)  # soft penalty below best
    else:
        raise ValueError(acquisition)
    if cost_aware:
        # EI-per-second (Snoek et al., 2012): divide the positive
        # acquisition mass by the predicted measurement cost, relative to
        # the mean observed cost so the units cancel; ``cost_alpha`` in
        # [0, 1] ramps the trade-off in as the wall clock runs out.
        cmu_s, _ = _posterior_core(cost_params, X, cost_y, mask, Xs, kind,
                                   cost_noise_row)
        log_cost = cmu_s * cost_std + cost_mean
        rel = jnp.exp(log_cost) / jnp.maximum(mean_cost, 1e-9)
        rel = jnp.clip(rel, 1e-2, 1e2) ** cost_alpha
        acq = jnp.where(acq > 0, acq / rel, acq * rel)
    ranked = jnp.where(cand_mask > 0, acq, -jnp.inf)
    order = jnp.argsort(-ranked, stable=True)
    return order, acq


def jit_cache_entries() -> int:
    """Total compiled-variant count across this module's jitted functions.

    The compile-once contract (and the ``bench-smoke`` CI gate) is that
    this number stays flat once the bucket schedule is warm: history
    growth within a bucket must not add entries.
    """
    # _cache_size is a private jax API; degrade to 0 (observability only)
    # rather than breaking the ask() path if a future jax drops it
    return sum(getattr(f, "_cache_size", lambda: 0)()
               for f in (_neg_mll, _fit, _posterior, _acq_rank))


@dataclass
class GPResult:
    mu: np.ndarray
    sigma: np.ndarray


class GaussianProcess:
    """Fit on (X in [0,1]^d, y); query posterior at candidate points.

    All device computation runs on bucketed/padded shapes (see module
    docstring), so repeated fits on a growing training set reuse the
    compiled executables.  ``fit(..., params0=prev.params)`` warm-starts
    the hyperparameter optimization with the short ``warm_steps``
    schedule.
    """

    def __init__(self, kind: str = "matern52", fit_steps: int = 120,
                 warm_steps: int = 30, lr: float = 0.05,
                 min_bucket: int = MIN_BUCKET):
        self.kind = kind
        self.fit_steps = fit_steps
        self.warm_steps = warm_steps
        self.lr = lr
        self.min_bucket = min_bucket
        self._params = None
        self._X = None       # padded (B, d)
        self._y = None       # padded (B,), standardized
        self._mask = None    # (B,) float prefix mask
        self._noise_row = None  # padded (B,) per-row noise scale, or None
        self._y_mean = 0.0
        self._y_std = 1.0
        #: observability: did the most recent fit() warm-start from params0?
        self.last_fit_was_warm = False

    @property
    def params(self) -> Optional[Dict]:
        """Fitted hyperparameters (warm-start handle for the next fit)."""
        return self._params

    def _padded(self, X: np.ndarray, y: np.ndarray, dtype):
        n = X.shape[0]
        b = bucket_size(n, self.min_bucket)
        Xp = jnp.asarray(_pad_rows(np.asarray(X, np.float64), b), dtype)
        yp = jnp.asarray(_pad_rows(np.asarray(y, np.float64), b), dtype)
        mask = jnp.asarray((np.arange(b) < n).astype(np.float64), dtype)
        return Xp, yp, mask

    def fit(self, X: np.ndarray, y: np.ndarray,
            params0: Optional[Dict] = None,
            noise_scale: Optional[np.ndarray] = None) -> "GaussianProcess":
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        yn = np.asarray(y, np.float64)
        self._y_mean = float(yn.mean())
        self._y_std = float(yn.std() + 1e-9)
        y_std = (yn - self._y_mean) / self._y_std
        Xp, yp, mask = self._padded(np.asarray(X), y_std, dtype)
        if noise_scale is None:
            nrow = None
        else:
            # per-row observation-noise scale (transfer warm starts inflate
            # prior-workload rows); padded rows get 1.0, which the mask
            # makes irrelevant anyway
            ns = np.asarray(noise_scale, np.float64)
            padded = np.ones(int(Xp.shape[0]), np.float64)
            padded[: ns.shape[0]] = ns
            nrow = jnp.asarray(padded, dtype)
        self._noise_row = nrow
        d = Xp.shape[1]
        cold = {
            "log_ls": jnp.full((d,), np.log(0.3), dtype),
            "log_sigma2": jnp.asarray(0.0, dtype),
            "log_noise": jnp.asarray(np.log(1e-3), dtype),
        }
        warm = params0 is not None
        self.last_fit_was_warm = warm
        init = params0 if warm else cold
        steps = self.warm_steps if warm else self.fit_steps
        fitted = _fit(init, Xp, yp, mask, self.kind, steps, self.lr, nrow)
        # fp32 robustness: if the fitted hyperparameters make the Cholesky
        # blow up (near-singular K), fall back to safe defaults with a
        # larger noise floor; a diverged warm start additionally gets a
        # full cold refit before giving up.
        nll = _neg_mll(fitted, Xp, yp, mask, self.kind, nrow)
        if not bool(jnp.isfinite(nll)):
            if warm:
                fitted = _fit(cold, Xp, yp, mask, self.kind,
                              self.fit_steps, self.lr, nrow)
                nll = _neg_mll(fitted, Xp, yp, mask, self.kind, nrow)
            if not bool(jnp.isfinite(nll)):
                fitted = {
                    "log_ls": jnp.full_like(cold["log_ls"], np.log(0.3)),
                    "log_sigma2": jnp.zeros_like(cold["log_sigma2"]),
                    "log_noise": jnp.full_like(cold["log_noise"], np.log(1e-2)),
                }
        self._params = fitted
        self._X, self._y, self._mask = Xp, yp, mask
        return self

    def _padded_candidates(self, Xs: np.ndarray):
        m = Xs.shape[0]
        b = bucket_size(m, self.min_bucket)
        Xsp = jnp.asarray(_pad_rows(np.asarray(Xs, np.float64), b),
                          self._X.dtype)
        cmask = jnp.asarray((np.arange(b) < m).astype(np.float64),
                            self._X.dtype)
        return Xsp, cmask, m

    def posterior(self, Xs: np.ndarray) -> GPResult:
        assert self._params is not None, "fit first"
        Xsp, _, m = self._padded_candidates(Xs)
        mu, var = _posterior(self._params, self._X, self._y, self._mask,
                             Xsp, self.kind, self._noise_row)
        mu, var = np.asarray(mu)[:m], np.asarray(var)[:m]
        if not np.isfinite(mu).all():  # last-resort refit with big noise
            safe = dict(self._params)
            safe["log_noise"] = jnp.full_like(self._params["log_noise"],
                                              np.log(1e-1))
            mu, var = _posterior(safe, self._X, self._y, self._mask,
                                 Xsp, self.kind, self._noise_row)
            mu, var = np.asarray(mu)[:m], np.asarray(var)[:m]
        mu = np.nan_to_num(mu, nan=0.0) * self._y_std + self._y_mean
        sigma = np.sqrt(np.clip(np.nan_to_num(var, nan=1.0), 1e-12, None)) * self._y_std
        return GPResult(mu, sigma)

    def acquisition_rank(self, Xs: np.ndarray, acquisition: str,
                         y_best: float, kappa: float = 2.0,
                         cost_gp: Optional["GaussianProcess"] = None,
                         cost_alpha: float = 1.0,
                         mean_cost: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
        """Rank candidates by acquisition in one fused jitted call.

        Returns ``(order, acq)`` restricted to the live candidates:
        ``order`` walks indices of ``Xs`` by descending acquisition.
        ``cost_gp`` (a GP fit on log measurement cost over the same
        training inputs) switches on EI-per-second weighting.
        """
        assert self._params is not None, "fit first"
        Xsp, cmask, m = self._padded_candidates(Xs)
        eps = 1e-3 * max(abs(y_best), 1.0)
        cost_aware = cost_gp is not None
        if cost_aware:
            assert cost_gp._y.shape == self._y.shape, \
                "cost GP must be fit on the same (padded) training inputs"
            cparams, cy = cost_gp._params, cost_gp._y
            cnrow = cost_gp._noise_row
            cmean, cstd = cost_gp._y_mean, cost_gp._y_std
        else:  # same-shape dummies keep the traced signature stable
            cparams, cy = self._params, self._y
            cnrow = None
            cmean, cstd = 0.0, 1.0
        dt = self._X.dtype

        def rank(params):
            order, acq = _acq_rank(
                params, self._X, self._y, self._mask, Xsp, cmask,
                jnp.asarray(self._y_mean, dt), jnp.asarray(self._y_std, dt),
                jnp.asarray(y_best, dt), jnp.asarray(kappa, dt),
                jnp.asarray(eps, dt),
                cparams, cy, jnp.asarray(cmean, dt), jnp.asarray(cstd, dt),
                jnp.asarray(cost_alpha, dt), jnp.asarray(mean_cost, dt),
                self.kind, acquisition, cost_aware,
                self._noise_row, cnrow)
            return np.asarray(order), np.asarray(acq)[:m]

        order, acq = rank(self._params)
        if not np.isfinite(acq).all():  # same fp32 last resort as posterior():
            safe = dict(self._params)   # re-rank with a big noise floor
            safe["log_noise"] = jnp.full_like(self._params["log_noise"],
                                              np.log(1e-1))
            order, acq = rank(safe)
        return order[order < m], acq

    @property
    def lengthscales(self) -> np.ndarray:
        return np.exp(np.asarray(self._params["log_ls"]))
