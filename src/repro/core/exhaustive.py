"""Exhaustive grid sweep (paper §4.3 Fig. 6 + the §1 cost argument)."""
from __future__ import annotations

from typing import Dict, Iterator

from repro.core.engine import Engine
from repro.core.history import History
from repro.core.space import SearchSpace


class Exhaustive(Engine):
    name = "exhaustive"

    def __init__(self, space: SearchSpace, seed: int = 0):
        super().__init__(space, seed)
        self._it: Iterator[Dict] = space.enumerate()

    def suggest(self, history: History) -> Dict:
        return next(self._it)
