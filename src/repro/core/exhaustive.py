"""Exhaustive grid sweep (paper §4.3 Fig. 6 + the §1 cost argument)."""
from __future__ import annotations

from typing import Dict, Iterator, List

from repro.core.engine import Engine
from repro.core.history import History
from repro.core.space import SearchSpace


class Exhaustive(Engine):
    name = "exhaustive"

    def __init__(self, space: SearchSpace, seed: int = 0):
        super().__init__(space, seed)
        self._it: Iterator[Dict] = space.enumerate()

    def ask(self, n: int, history: History) -> List[Dict]:
        batch: List[Dict] = []
        for _ in range(n):
            try:
                batch.append(next(self._it))
            except StopIteration:
                break  # grid exhausted; [] tells the tuner to stop cleanly
        return batch
