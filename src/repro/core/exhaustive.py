"""Exhaustive grid sweep (paper §4.3 Fig. 6 + the §1 cost argument)."""
from __future__ import annotations

from typing import Dict, Iterator, List

from repro.core.engine import Engine
from repro.core.history import History
from repro.core.space import SearchSpace


class Exhaustive(Engine):
    name = "exhaustive"

    #: asks are a stateful enumeration, not independent suggestions: each
    #: ask consumes the one-shot grid iterator, so a point the transfer
    #: pre-filter discarded would never be re-proposed and the "exhaustive"
    #: sweep would silently skip part of the grid.  Opt out entirely.
    prefilter_safe = False

    def __init__(self, space: SearchSpace, seed: int = 0):
        super().__init__(space, seed)
        self._it: Iterator[Dict] = space.enumerate()

    def ask(self, n: int, history: History) -> List[Dict]:
        batch: List[Dict] = []
        while len(batch) < n:
            try:
                p = next(self._it)
            except StopIteration:
                break  # grid exhausted; [] tells the tuner to stop cleanly
            # skip grid points the history already holds (or that are in
            # flight): a resumed sweep continues where the crash left off
            # instead of burning budget re-recording memoized repeats
            if history.lookup(p) is not None or history.pending(p):
                continue
            batch.append(p)
        return batch
