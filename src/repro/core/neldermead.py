"""Nelder-Mead simplex engine on the integer-stepped grid (paper §2.2;
TensorTuner's algorithm).

Standard reflection / expansion / contraction / shrink in the unit-cube
encoding, with every probe snapped to the grid.  The engine is a state
machine driven by ``ask``/``tell`` so it plugs into the same iteration
loop as BO and GA; NMS's known failure mode — clustering around local
optima and never touching parameter-range extremes — is exactly what the
paper's Table 2 measures.

Batching: NMS is inherently sequential, so ``ask(n>1, ...)`` pads the
primary probe with *speculative* candidates — the expansion and both
contraction probes that would follow a reflection, or the whole
precomputed shrink queue.

Completion-order reconciliation: under the completion-driven tuner loop,
``tell`` arrives one result at a time in *completion* order, so a
speculative probe can land before the primary it was speculating past.
``tell`` therefore stashes every reported result in a buffer and drains
the buffer through the state machine for as long as the value the
machine expects next is available.  A probe that completes late (or was
never needed) simply stays buffered and is consumed the moment the
machine reaches it — or never, which is free, since the tuner's history
memoizes it anyway.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import Engine
from repro.core.history import History
from repro.core.space import SearchSpace

ALPHA, GAMMA, RHO, SIGMA = 1.0, 2.0, 0.5, 0.5


class NelderMead(Engine):
    name = "nms"
    # the speculative-batch state machine expects every asked probe to be
    # told eventually; dropping probes (transfer pre-filter) would wedge it
    prefilter_safe = False

    def __init__(self, space: SearchSpace, seed: int = 0, init_radius: float = 0.25):
        super().__init__(space, seed)
        d = space.n_dims
        x0 = self.rng.random(d)
        verts = [x0]
        for i in range(d):
            v = x0.copy()
            v[i] = np.clip(v[i] + (init_radius if v[i] < 0.5 else -init_radius), 0, 1)
            verts.append(v)
        self._pending: List[np.ndarray] = verts  # vertices awaiting values
        self._simplex: List[Tuple[np.ndarray, float]] = []
        self._phase = "init"
        self._xr: Optional[np.ndarray] = None
        self._fr: Optional[float] = None
        self._xprobe: Optional[np.ndarray] = None
        self._shrink_queue: List[np.ndarray] = []
        self._told: Dict[Tuple, Tuple[Dict, float]] = {}  # completion buffer

    # -- state machine --------------------------------------------------------
    def _order(self):
        self._simplex.sort(key=lambda t: -t[1])  # best (max) first

    def _centroid(self) -> np.ndarray:
        pts = [x for x, _ in self._simplex[:-1]]
        return np.mean(pts, axis=0)

    def _primary(self) -> Dict:
        """The one point the state machine needs next."""
        if self._phase == "init":
            return self.space.decode(self._pending[len(self._simplex)])
        if self._phase in ("reflect", "expand", "contract", "shrink"):
            return self.space.decode(self._xprobe)
        raise RuntimeError(self._phase)

    def ask(self, n: int, history: History) -> List[Dict]:
        if self._phase == "init":
            # the remaining simplex vertices are a natural batch
            lo = len(self._simplex)
            hi = min(lo + n, len(self._pending))
            batch, keys = [], set()
            for x in self._pending[lo:hi]:
                p = self.space.decode(x)
                k = self.space.key(p)
                if k not in keys:  # distinct vertices may snap to one cell
                    keys.add(k)
                    batch.append(p)
            return batch

        primary = self._primary()
        batch = [primary]
        keys = {self.space.key(primary)}

        def spec(x: np.ndarray) -> None:
            p = self.space.decode(x)
            k = self.space.key(p)
            if k not in keys:
                keys.add(k)
                batch.append(p)

        if self._phase == "reflect" and n > 1 and len(self._simplex) >= 2:
            # speculate on every outcome of the reflection step
            xc = self._centroid()
            xr = self.space.encode(primary)  # grid-snapped reflection point
            spec(np.clip(xc + GAMMA * (xr - xc), 0, 1))        # expansion
            spec(np.clip(xc + RHO * (xr - xc), 0, 1))          # outside contraction
            spec(np.clip(xc + RHO * (self._simplex[-1][0] - xc), 0, 1))  # inside
        elif self._phase == "shrink":
            for x in self._shrink_queue:  # precomputed: measure them all
                spec(x)
        return batch[:n]

    def _tell(self, observations) -> None:
        for o in observations:
            self._told.setdefault(self.space.key(o.point), (o.point, o.value))
        # drain: consume buffered results for as long as the state machine's
        # next expected point has already been measured (handles primaries
        # and speculative probes completing in any order)
        while True:
            exp = self._primary()
            k = self.space.key(exp)
            if k not in self._told:
                break  # next expected value still in flight / never asked
            p, v = self._told.pop(k)
            self.observe(p, v)

    def observe(self, point: Dict, value: float) -> None:
        if not np.isfinite(value):
            value = -np.inf
        x = self.space.encode(point)
        if self._phase == "init":
            self._simplex.append((x, value))
            if len(self._simplex) == len(self._pending):
                self._start_reflect()
            return

        if self._phase == "reflect":
            self._order()
            f_best = self._simplex[0][1]
            f_second_worst = self._simplex[-2][1]
            f_worst = self._simplex[-1][1]
            self._xr, self._fr = x, value
            if value > f_best:
                xc = self._centroid()
                self._xprobe = np.clip(xc + GAMMA * (self._xr - xc), 0, 1)
                self._phase = "expand"
            elif value > f_second_worst:
                self._simplex[-1] = (self._xr, value)
                self._start_reflect()
            else:
                xc = self._centroid()
                base = self._xr if value > f_worst else self._simplex[-1][0]
                self._xprobe = np.clip(xc + RHO * (base - xc), 0, 1)
                self._phase = "contract"
            return

        if self._phase == "expand":
            if value > self._fr:
                self._simplex[-1] = (x, value)
            else:
                self._simplex[-1] = (self._xr, self._fr)
            self._start_reflect()
            return

        if self._phase == "contract":
            f_worst = self._simplex[-1][1]
            if value > max(f_worst, self._fr if self._fr is not None else -np.inf):
                self._simplex[-1] = (x, value)
                self._start_reflect()
            else:  # shrink toward best
                self._order()
                best = self._simplex[0][0]
                self._shrink_queue = [
                    np.clip(best + SIGMA * (xi - best), 0, 1)
                    for xi, _ in self._simplex[1:]
                ]
                self._simplex = [self._simplex[0]]
                self._phase = "shrink"
                self._xprobe = self._shrink_queue.pop(0)
            return

        if self._phase == "shrink":
            self._simplex.append((x, value))
            if self._shrink_queue:
                self._xprobe = self._shrink_queue.pop(0)
            else:
                self._start_reflect()
            return

    def _start_reflect(self):
        self._order()
        xc = self._centroid()
        worst = self._simplex[-1][0]
        self._xprobe = np.clip(xc + ALPHA * (xc - worst), 0, 1)
        self._phase = "reflect"
