"""Parameter search space (paper §2.2, Table 1).

Dimensions are integer ranges with (min, max, step) — exactly the paper's
tunable-range formulation — or categoricals.  Points are dicts
``{name: value}``.  The space encodes points into the unit hypercube for
the GP surrogate and decodes/snaps arbitrary unit-cube vectors back onto
the grid.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class IntDim:
    name: str
    lo: int
    hi: int
    step: int = 1

    @property
    def values(self) -> Tuple[int, ...]:
        return tuple(range(self.lo, self.hi + 1, self.step))


@dataclass(frozen=True)
class CatDim:
    name: str
    choices: Tuple

    @property
    def values(self) -> Tuple:
        return tuple(self.choices)


Dim = Union[IntDim, CatDim]


class SearchSpace:
    def __init__(self, dims: Sequence[Dim]):
        assert dims, "empty search space"
        self.dims: List[Dim] = list(dims)
        names = [d.name for d in self.dims]
        assert len(set(names)) == len(names), f"duplicate dims: {names}"

    @classmethod
    def from_dicts(cls, dicts: Sequence[dict]) -> "SearchSpace":
        dims: List[Dim] = []
        for d in dicts:
            if d["type"] == "int":
                dims.append(IntDim(d["name"], d["min"], d["max"], d.get("step", 1)))
            elif d["type"] == "cat":
                dims.append(CatDim(d["name"], tuple(d["choices"])))
            else:
                raise ValueError(d)
        return cls(dims)

    def to_dicts(self) -> List[dict]:
        """Inverse of :meth:`from_dicts` — the wire/checkpoint form the
        tuning service serializes job search spaces as."""
        out: List[dict] = []
        for d in self.dims:
            if isinstance(d, IntDim):
                out.append({"type": "int", "name": d.name, "min": d.lo,
                            "max": d.hi, "step": d.step})
            else:
                out.append({"type": "cat", "name": d.name,
                            "choices": list(d.choices)})
        return out

    # -- basics --------------------------------------------------------------
    @property
    def n_dims(self) -> int:
        return len(self.dims)

    @property
    def names(self) -> List[str]:
        return [d.name for d in self.dims]

    def grid_size(self) -> int:
        n = 1
        for d in self.dims:
            n *= len(d.values)
        return n

    def enumerate(self) -> Iterator[Dict]:
        value_lists = [d.values for d in self.dims]
        for combo in itertools.product(*value_lists):
            yield dict(zip(self.names, combo))

    def key(self, point: Dict) -> Tuple:
        """Hashable identity of a point (memoization key)."""
        return tuple(point[d.name] for d in self.dims)

    def validate(self, point: Dict) -> bool:
        for d in self.dims:
            if point.get(d.name) not in d.values:
                return False
        return True

    # -- encoding ------------------------------------------------------------
    def encode(self, point: Dict) -> np.ndarray:
        """point -> unit hypercube [0, 1]^d."""
        u = np.zeros(self.n_dims)
        for i, d in enumerate(self.dims):
            vals = d.values
            idx = vals.index(point[d.name])
            u[i] = idx / max(len(vals) - 1, 1)
        return u

    def decode(self, u: np.ndarray) -> Dict:
        """unit-cube vector -> nearest grid point."""
        point = {}
        for i, d in enumerate(self.dims):
            vals = d.values
            idx = int(round(np.clip(u[i], 0.0, 1.0) * (len(vals) - 1)))
            point[d.name] = vals[idx]
        return point

    def encode_many(self, points: Sequence[Dict]) -> np.ndarray:
        return np.stack([self.encode(p) for p in points]) if points else np.zeros((0, self.n_dims))

    # -- sampling ------------------------------------------------------------
    def sample(self, rng: np.random.Generator, n: int = 1) -> List[Dict]:
        out = []
        for _ in range(n):
            out.append({d.name: d.values[rng.integers(len(d.values))] for d in self.dims})
        return out

    def sample_lhs(self, rng: np.random.Generator, n: int) -> List[Dict]:
        """Latin-hypercube-ish init: stratified per dimension."""
        cols = []
        for d in self.dims:
            strata = (np.arange(n) + rng.random(n)) / n
            rng.shuffle(strata)
            cols.append(strata)
        U = np.stack(cols, axis=1)
        return [self.decode(U[i]) for i in range(n)]

    def perturb(self, rng: np.random.Generator, point: Dict, radius: int = 1) -> Dict:
        """Neighbor: move a random subset of dims by +-radius grid steps."""
        new = dict(point)
        k = max(1, rng.integers(1, self.n_dims + 1) // 2)
        for i in rng.choice(self.n_dims, size=k, replace=False):
            d = self.dims[i]
            vals = d.values
            idx = vals.index(new[d.name])
            idx = int(np.clip(idx + rng.integers(-radius, radius + 1), 0, len(vals) - 1))
            new[d.name] = vals[idx]
        return new
