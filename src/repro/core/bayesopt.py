"""Bayesian optimization engine (paper §2.2).

GP surrogate (gp.py) + acquisition maximization over a candidate set.
Acquisitions:

* ``smsego`` (paper default) — for each candidate, the optimistic estimate
  mu + c*sigma is compared against the best evaluation observed so far;
  the candidate maximizing the potential *extension* of the best value is
  selected (the single-objective S-metric-selection gain).
* ``ei``  — expected improvement (closed form).
* ``ucb`` — upper confidence bound.

The candidate set is the full grid when small, otherwise random samples
plus local perturbations of the incumbent (exploitation neighborhood).

``ask(n, ...)`` fits the surrogate once and returns the top-n candidates
by acquisition value (deduplicated, unseen), so a parallel executor can
measure a whole acquisition batch per GP fit; ``ask(1, ...)`` selects
exactly the argmax the single-point path always did.

Compile-once suggestion path
----------------------------

Under the completion-driven tuner loop every completed measurement
triggers a fresh ``ask``, so suggestion cost is on the critical path.
Three mechanisms keep it at microseconds of XLA instead of a fresh
compile (see ``gp.py`` for the shape discipline):

* the GP is **persistent** across asks and refits are **warm-started**
  from the previous hyperparameters (short refinement schedule) once the
  training set reaches ``warm_start_min_n`` rows — below that a cold fit
  is a few jitted milliseconds, the posterior is still moving fast
  enough that stale hyperparameters hurt, and the sequential suggestion
  trace stays bit-for-bit identical to the pre-compile-once engine
  (pinned by ``tests/golden/ask_tell_traces.json``); above it each Adam
  step pays a full Cholesky, which is exactly where 30 warm steps beat
  120 cold ones;
* training and candidate arrays are padded to power-of-two buckets, so
  history growth within a bucket reuses compiled executables;
* acquisition scoring + ranking runs as one fused jitted call
  (``GaussianProcess.acquisition_rank``) — the posterior never
  round-trips to host.  ``jit_acquisition=False`` selects the vectorized
  numpy scoring path instead (same ranking, no fusion).

Cost-aware acquisition (``cost_aware=True``) divides the positive
acquisition mass by a per-candidate predicted measurement cost from a
second GP fit on log ``cost_seconds`` (EI-per-second, Snoek et al.,
2012).  When the tuner reports wall-clock budget pressure via
``note_budget``, the weighting ramps in as the deadline approaches, so
the engine prefers cheap probes exactly when the remaining budget can
only afford them.  Per-ask suggestion latency and jit-cache growth are
recorded on ``ask_seconds`` / ``jit_misses`` for the bench gate.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import gp as gp_module
from repro.core.engine import Engine
from repro.core.gp import GaussianProcess
from repro.core.history import History
from repro.core.space import SearchSpace

_SQRT2 = math.sqrt(2.0)

try:  # scipy ships with jax; erf over arrays without a Python loop
    from scipy.special import erf as _erf
except ImportError:  # pragma: no cover - scipy-less fallback
    def _erf(z):
        # Abramowitz & Stegun 7.1.26 — vectorized, |err| < 1.5e-7
        z = np.asarray(z, np.float64)
        sign = np.sign(z)
        t = 1.0 / (1.0 + 0.3275911 * np.abs(z))
        poly = t * (0.254829592 + t * (-0.284496736 + t * (
            1.421413741 + t * (-1.453152027 + t * 1.061405429))))
        return sign * (1.0 - poly * np.exp(-z * z))


def _norm_cdf(z):
    return 0.5 * (1.0 + _erf(np.asarray(z) / _SQRT2))


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


def _pearson(pred, actual) -> Optional[float]:
    p = np.asarray(pred, np.float64)
    a = np.asarray(actual, np.float64)
    if p.size != a.size or p.size < 2:
        return None
    if float(p.std()) == 0.0 or float(a.std()) == 0.0:
        return None
    return float(np.corrcoef(p, a)[0, 1])


@dataclass
class TransferPrior:
    """Prior observations from neighbor workloads (transfer warm-start).

    Built from :meth:`TuningCorpus.prior_observations` rows: encoded
    points + values + per-row workload distances.  The engine seeds its
    surrogate with these rows under inflated observation noise
    (:meth:`noise_scale` — proportional to workload distance, growing
    quadratically as real observations accumulate so the prior fades),
    and :meth:`predict` gives the cheap Nadaraya-Watson estimate the
    negative-transfer guard compares against the first real
    measurements.
    """

    points: List[Dict]
    X: np.ndarray           # encoded (k, d), no fidelity column
    y: np.ndarray           # (k,)
    distances: np.ndarray   # (k,) workload distance per row, in [0, 1]
    fidelities: np.ndarray = field(default=None)  # (k,), default all-1

    def __post_init__(self):
        self.X = np.asarray(self.X, np.float64)
        self.y = np.asarray(self.y, np.float64)
        self.distances = np.asarray(self.distances, np.float64)
        if self.fidelities is None:
            self.fidelities = np.ones_like(self.y)
        else:
            self.fidelities = np.asarray(self.fidelities, np.float64)

    @classmethod
    def from_rows(cls, space: SearchSpace, rows: List[Dict]) -> "TransferPrior":
        """Build from corpus ``prior_observations`` rows."""
        pts = [dict(r["point"]) for r in rows]
        return cls(
            points=pts,
            X=space.encode_many(pts),
            y=np.asarray([r["value"] for r in rows], np.float64),
            distances=np.asarray([r.get("distance", 0.0) for r in rows],
                                 np.float64),
            fidelities=np.asarray([r.get("fidelity", 1.0) for r in rows],
                                  np.float64),
        )

    def __len__(self) -> int:
        return int(self.y.shape[0])

    def best_point(self) -> Dict:
        return dict(self.points[int(np.argmax(self.y))])

    def predict(self, Xq: np.ndarray) -> np.ndarray:
        """Nadaraya-Watson estimate at encoded query points (RBF weights
        in the unit-cube encoding) — cheap enough for guard checks and
        candidate pre-filtering without a GP fit."""
        Xq = np.atleast_2d(np.asarray(Xq, np.float64))
        d2 = ((Xq[:, None, :] - self.X[None, :, :]) ** 2).sum(-1)
        w = np.exp(-d2 / (2.0 * 0.25 ** 2))
        den = w.sum(axis=1)
        num = w @ self.y
        return np.where(den > 1e-12, num / np.maximum(den, 1e-12),
                        float(self.y.mean()))

    def noise_scale(self, n_real: int, decay: int) -> np.ndarray:
        """Per-row observation-noise inflation (>= 1): base inflation
        proportional to workload distance, times a quadratic ramp in the
        real-observation count so prior rows fade as evidence arrives."""
        ramp = 1.0 + 9.0 * (n_real / max(decay, 1)) ** 2
        return (1.0 + 3.0 * self.distances) * ramp


class BayesOpt(Engine):
    name = "bo"

    def __init__(
        self,
        space: SearchSpace,
        seed: int = 0,
        n_init: int = 8,
        acquisition: str = "smsego",
        kappa: float = 2.0,
        max_candidates: int = 4096,
        kernel: str = "matern52",
        cost_aware: bool = False,
        jit_acquisition: bool = True,
        warm_start: bool = True,
        warm_start_min_n: int = 64,
        fidelity_feature: bool = False,
        transfer_prior: Optional[TransferPrior] = None,
        transfer_decay: int = 24,
        transfer_guard_n: int = 3,
    ):
        super().__init__(space, seed)
        self.n_init = min(n_init, max(2, space.grid_size() // 2))
        self.acquisition = acquisition
        self.kappa = kappa
        self.max_candidates = max_candidates
        self.kernel = kernel
        self.cost_aware = cost_aware
        self.jit_acquisition = jit_acquisition
        self.warm_start = warm_start
        self.warm_start_min_n = warm_start_min_n
        #: multi-fidelity mode: append each observation's fidelity as an
        #: extra GP input column (candidates are scored at fidelity 1.0),
        #: so partial measurements inform the surrogate without being
        #: mistaken for exact values.  Off by default: the single-fidelity
        #: suggestion trace stays bit-for-bit identical.
        self.fidelity_feature = fidelity_feature
        #: transfer warm-start: prior observations from neighbor workloads
        #: (None = cold start, the historical bit-for-bit path)
        self.transfer_prior = (transfer_prior if transfer_prior is not None
                               and len(transfer_prior) > 0 else None)
        self.transfer_decay = transfer_decay
        self.transfer_guard_n = transfer_guard_n
        self._prior_dropped = False   # negative-transfer guard tripped/retired
        self._prior_checked = False   # guard runs once
        self._prior_best_point = (self.transfer_prior.best_point()
                                  if self.transfer_prior is not None else None)
        self._init_points = None
        self._gp: Optional[GaussianProcess] = None
        self._cost_gp: Optional[GaussianProcess] = None
        self._grid_cache = None  # small grids: (points, encodings), immutable
        # per-ask observability (consumed by benchmarks + the CI gate)
        self.ask_seconds: List[float] = []
        self.jit_misses: List[int] = []

    def _candidates(self, history: History):
        """Return ``(cands, Xs)``: candidate points + their encodings.

        Small grids are enumerated and encoded exactly once per engine
        (the grid is immutable); each ask just slices out the unseen
        rows, keeping host-side Python work off the per-completion
        suggestion path.
        """
        if self.space.grid_size() <= self.max_candidates:
            if self._grid_cache is None:
                pts = list(self.space.enumerate())
                self._grid_cache = (pts, self.space.encode_many(pts))
            pts, enc = self._grid_cache
            idx = [i for i, p in enumerate(pts) if not history.seen(p)]
            if not idx:
                return pts, enc
            return [pts[i] for i in idx], enc[idx]
        cands = self.space.sample(self.rng, self.max_candidates // 2)
        # local neighborhood of the incumbent (exploitation half); in
        # fidelity mode the incumbent must be a full measurement — a
        # partial value's optimistic bias would center exploitation on
        # measurement noise (same guard as y_best in _ask)
        if (self._prior_best_point is not None
                and not np.isfinite(history.values()).any()):
            # transfer mode before the first finite real measurement:
            # exploit around the neighbor workload's best (the no-prior
            # path never reaches here without >= 2 finite values)
            best = self._prior_best_point
        else:
            best = history.best(full_fidelity_only=self.fidelity_feature and bool(
                np.any((history.fidelities() >= 1.0)
                       & np.isfinite(history.values())))).point
        for _ in range(self.max_candidates // 2):
            cands.append(self.space.perturb(self.rng, best, radius=2))
        seen_keys = set()
        out = []
        for c in cands:
            k = self.space.key(c)
            if k not in seen_keys and not history.seen(c):
                seen_keys.add(k)
                out.append(c)
        out = out or cands
        return out, self.space.encode_many(out)

    # -- surrogate maintenance ------------------------------------------------
    def _fit_surrogate(self, X: np.ndarray, y: np.ndarray,
                       noise_scale: Optional[np.ndarray] = None
                       ) -> GaussianProcess:
        """Refit the persistent GP, warm-starting from the previous fit.

        Warm-start policy: cold refits below ``warm_start_min_n`` rows
        (cheap under compile-once shapes, keeps the small-history
        suggestion trace bit-for-bit stable), warm refinement above
        (each Adam step pays a Cholesky there, so 30 warm steps beat
        120 cold ones).  ``noise_scale`` (transfer mode) inflates
        per-row observation noise for prior-workload rows.
        """
        if self._gp is None:
            self._gp = GaussianProcess(kind=self.kernel)
        params0 = (self._gp.params
                   if self.warm_start and X.shape[0] >= self.warm_start_min_n
                   else None)
        self._gp.fit(X, y, params0=params0, noise_scale=noise_scale)
        return self._gp

    def _fit_cost_model(self, X: np.ndarray,
                        history: History) -> Optional[GaussianProcess]:
        """GP over log measurement cost; None until >= 2 costs were paid."""
        if not self.cost_aware:
            return None
        costs = history.costs()
        paid = costs > 0
        if paid.sum() < 2 or float(costs[paid].std()) == 0.0:
            return None
        filled = np.where(paid, costs, costs[paid].mean())
        log_cost = np.log(np.maximum(filled, 1e-6))
        if self._cost_gp is None:
            self._cost_gp = GaussianProcess(kind=self.kernel)
        # same warm-start policy as the value GP: cold while small (the
        # cost posterior is still moving fast), warm refinement above
        params0 = (self._cost_gp.params
                   if self.warm_start and X.shape[0] >= self.warm_start_min_n
                   else None)
        self._cost_gp.fit(X, log_cost, params0=params0)
        return self._cost_gp

    def _cost_alpha(self) -> float:
        """EI-per-second exponent: full strength without budget info, else
        ramping 0 -> 1 as the wall-clock budget nears exhaustion."""
        frac = self.budget_fraction_remaining
        if frac is None:
            return 1.0
        return float(np.clip(1.0 - frac, 0.0, 1.0))

    # -- acquisition scoring --------------------------------------------------
    def _rank_numpy(self, gp: GaussianProcess, Xs: np.ndarray, y_best: float,
                    cost_gp: Optional[GaussianProcess]) -> np.ndarray:
        """Vectorized numpy scoring fallback (no host/device fusion)."""
        post = gp.posterior(Xs)
        if self.acquisition == "ucb":
            acq = post.mu + self.kappa * post.sigma
        elif self.acquisition == "ei":
            z = (post.mu - y_best) / np.maximum(post.sigma, 1e-12)
            acq = (post.mu - y_best) * _norm_cdf(z) + post.sigma * _norm_pdf(z)
        elif self.acquisition == "smsego":
            # single-objective SMSego gain: how far the optimistic estimate
            # extends the best observation (epsilon-dominance guard keeps
            # pure-exploitation candidates from pinning the search)
            optimistic = post.mu + self.kappa * post.sigma
            eps = 1e-3 * max(abs(y_best), 1.0)
            gain = optimistic - (y_best + eps)
            acq = np.where(gain > 0, gain, gain * 1e-3)  # soft penalty below best
        else:
            raise ValueError(self.acquisition)
        if cost_gp is not None:
            rel = (np.exp(cost_gp.posterior(Xs).mu)
                   / max(self.mean_cost_seconds, 1e-9))
            rel = np.clip(rel, 1e-2, 1e2) ** self._cost_alpha()
            acq = np.where(acq > 0, acq / rel, acq * rel)
        return np.argsort(-acq, kind="stable")

    def _rank(self, gp: GaussianProcess, Xs: np.ndarray, y_best: float,
              cost_gp: Optional[GaussianProcess]) -> np.ndarray:
        if not self.jit_acquisition:
            return self._rank_numpy(gp, Xs, y_best, cost_gp)
        order, _ = gp.acquisition_rank(
            Xs, self.acquisition, y_best, kappa=self.kappa,
            cost_gp=cost_gp, cost_alpha=self._cost_alpha(),
            mean_cost=self.mean_cost_seconds)
        return order

    def ask(self, n: int, history: History) -> List[Dict]:
        t0 = time.perf_counter()
        entries0 = gp_module.jit_cache_entries()
        self.last_ask_ranked = None  # set by _ask_transfer when it pads
        try:
            return self._ask(n, history)
        finally:
            self.ask_seconds.append(time.perf_counter() - t0)
            self.jit_misses.append(gp_module.jit_cache_entries() - entries0)

    # -- transfer warm-start --------------------------------------------------
    def _active_prior(self, history: History) -> Optional[TransferPrior]:
        """The transfer prior if it should still shape this ask, else None.

        The prior retires after ``transfer_decay`` real observations (by
        then its inflated noise has drowned it anyway), and is dropped
        permanently — negative-transfer guard — if its predictions
        anti-correlate with the first ``transfer_guard_n`` finite real
        measurements.
        """
        if self.transfer_prior is None or self._prior_dropped:
            return None
        if len(history) >= self.transfer_decay:
            self._prior_dropped = True
            return None
        if not self._prior_checked:
            X, y = history.encoded()
            finite = np.isfinite(y)
            if int(finite.sum()) >= self.transfer_guard_n:
                self._prior_checked = True
                agree = _pearson(self.transfer_prior.predict(X[finite]),
                                 y[finite])
                if agree is not None and agree < 0.0:
                    self._prior_dropped = True
                    return None
        return self.transfer_prior

    def _ask_transfer(self, n: int, history: History,
                      prior: TransferPrior) -> List[Dict]:
        """Ask with the surrogate seeded by prior-workload observations.

        No LHS init phase: the prior already covers the space, which is
        where the warm start's measurement savings come from.  Prior rows
        enter the GP under inflated per-row noise; the cost model stays
        off while the prior is active (prior rows carry no cost on this
        hardware, and the cost GP must share the value GP's padded
        training inputs).
        """
        batch: List[Dict] = []
        keys = set()

        def emit(point: Dict) -> None:
            keys.add(self.space.key(point))
            batch.append(point)

        n_real = len(history)
        if n_real:
            X, y = history.encoded()
        else:
            X = np.zeros((0, prior.X.shape[1]))
            y = np.zeros((0,))
        finite = np.isfinite(y)
        # failed real configs get the worst value on hand (pessimism)
        floor = float(y[finite].min()) if finite.any() else float(prior.y.min())
        y_real = np.where(finite, y, floor)
        Xall = np.concatenate([prior.X, X], axis=0)
        yall = np.concatenate([prior.y, y_real])
        noise = np.concatenate([prior.noise_scale(n_real, self.transfer_decay),
                                np.ones(y_real.shape[0])])
        if self.fidelity_feature:
            fid = np.concatenate([prior.fidelities, history.fidelities()]
                                 if n_real else [prior.fidelities])
            Xall = np.concatenate([Xall, fid[:, None]], axis=1)

        gp = self._fit_surrogate(Xall, yall, noise_scale=noise)
        cands, Xs = self._candidates(history)
        if self.fidelity_feature:
            Xs = np.concatenate([Xs, np.ones((Xs.shape[0], 1))], axis=1)
        # incumbent: best finite real measurement, else the prior's best
        y_best = (float(y[finite].max()) if finite.any()
                  else float(prior.y.max()))
        order = self._rank(gp, Xs, y_best, None)

        for i in order:
            if len(batch) == n:
                break
            c = cands[int(i)]
            k = self.space.key(c)
            if k in keys or history.seen(c) or history.pending(c):
                continue
            emit(dict(c))
        # everything past this index is an unranked random fill, not an
        # acquisition-ranked suggestion: report the boundary so the
        # tuner's pre-filter never promotes a fill over a ranked point
        self.last_ask_ranked = len(batch)
        while len(batch) < n:  # candidate set exhausted: random fill
            emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                              exclude=keys))
        return batch

    def _ask(self, n: int, history: History) -> List[Dict]:
        prior = self._active_prior(history)
        if prior is not None:
            return self._ask_transfer(n, history, prior)
        if self._init_points is None:
            self._init_points = self.space.sample_lhs(self.rng, self.n_init)
        batch: List[Dict] = []
        keys = set()

        def emit(point: Dict) -> None:
            keys.add(self.space.key(point))
            batch.append(point)

        # LHS init phase (possibly only the head of the batch)
        while (len(batch) < n
               and len(history) + history.n_pending() + len(batch) < self.n_init):
            idx = len(history) + history.n_pending() + len(batch)
            emit(self._unseen(history, self._init_points[idx], exclude=keys))
        if len(batch) == n:
            return batch

        X, y = history.encoded()
        finite = np.isfinite(y)
        if finite.sum() < 2:
            while len(batch) < n:
                emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                                  exclude=keys))
            return batch
        # failed configs (OOM etc.) get the worst finite value (pessimism)
        y = np.where(finite, y, y[finite].min())
        if self.fidelity_feature:
            # fidelity is an input feature: the GP learns how partial
            # measurements relate to full ones instead of treating a
            # cheap noisy value as ground truth
            X = np.concatenate([X, history.fidelities()[:, None]], axis=1)

        gp = self._fit_surrogate(X, y)
        cost_gp = self._fit_cost_model(X, history)
        cands, Xs = self._candidates(history)
        if self.fidelity_feature:
            # candidates are scored as full measurements
            Xs = np.concatenate([Xs, np.ones((Xs.shape[0], 1))], axis=1)
            # ... and the incumbent must be one too: a partial value's
            # optimistic bias would otherwise set a y_best no full
            # measurement can beat, collapsing the acquisition
            full = finite & (history.fidelities() >= 1.0)
            y_best = float(np.max(y[full])) if full.any() else float(np.max(y))
        else:
            y_best = float(np.max(y))
        order = self._rank(gp, Xs, y_best, cost_gp)

        # top-n by acquisition; stable sort so n=1 picks np.argmax's candidate
        for i in order:
            if len(batch) == n:
                break
            c = cands[int(i)]
            k = self.space.key(c)
            if k in keys or (len(batch) > 0 and
                             (history.seen(c) or history.pending(c))):
                continue
            emit(dict(c))
        while len(batch) < n:  # candidate set exhausted: random fill
            emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                              exclude=keys))
        return batch
