"""Bayesian optimization engine (paper §2.2).

GP surrogate (gp.py) + acquisition maximization over a candidate set.
Acquisitions:

* ``smsego`` (paper default) — for each candidate, the optimistic estimate
  mu + c*sigma is compared against the best evaluation observed so far;
  the candidate maximizing the potential *extension* of the best value is
  selected (the single-objective S-metric-selection gain).
* ``ei``  — expected improvement (closed form).
* ``ucb`` — upper confidence bound.

The candidate set is the full grid when small, otherwise random samples
plus local perturbations of the incumbent (exploitation neighborhood).

``ask(n, ...)`` fits the surrogate once and returns the top-n candidates
by acquisition value (deduplicated, unseen), so a parallel executor can
measure a whole acquisition batch per GP fit; ``ask(1, ...)`` selects
exactly the argmax the single-point path always did.

Under the completion-driven tuner loop, each completed measurement is
told back immediately and the freed worker's replacement point comes
from a *fresh* ``ask`` — i.e. the candidate set and surrogate refresh in
completion order, so every suggestion conditions on all measurements
finished so far (in-flight points are excluded via ``history.pending``).
Measured ``cost_seconds`` accumulate on the engine
(``mean_cost_seconds``) as the hook for cost-aware acquisition.
"""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

from repro.core.engine import Engine
from repro.core.gp import GaussianProcess
from repro.core.history import History
from repro.core.space import SearchSpace

_SQRT2 = math.sqrt(2.0)


def _norm_cdf(z):
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / _SQRT2))


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


class BayesOpt(Engine):
    name = "bo"

    def __init__(
        self,
        space: SearchSpace,
        seed: int = 0,
        n_init: int = 8,
        acquisition: str = "smsego",
        kappa: float = 2.0,
        max_candidates: int = 4096,
        kernel: str = "matern52",
    ):
        super().__init__(space, seed)
        self.n_init = min(n_init, max(2, space.grid_size() // 2))
        self.acquisition = acquisition
        self.kappa = kappa
        self.max_candidates = max_candidates
        self.kernel = kernel
        self._init_points = None

    def _candidates(self, history: History):
        if self.space.grid_size() <= self.max_candidates:
            cands = [p for p in self.space.enumerate() if not history.seen(p)]
            if cands:
                return cands
            return list(self.space.enumerate())
        cands = self.space.sample(self.rng, self.max_candidates // 2)
        # local neighborhood of the incumbent (exploitation half)
        best = history.best().point
        for _ in range(self.max_candidates // 2):
            cands.append(self.space.perturb(self.rng, best, radius=2))
        seen_keys = set()
        out = []
        for c in cands:
            k = self.space.key(c)
            if k not in seen_keys and not history.seen(c):
                seen_keys.add(k)
                out.append(c)
        return out or cands

    def ask(self, n: int, history: History) -> List[Dict]:
        if self._init_points is None:
            self._init_points = self.space.sample_lhs(self.rng, self.n_init)
        batch: List[Dict] = []
        keys = set()

        def emit(point: Dict) -> None:
            keys.add(self.space.key(point))
            batch.append(point)

        # LHS init phase (possibly only the head of the batch)
        while (len(batch) < n
               and len(history) + history.n_pending() + len(batch) < self.n_init):
            idx = len(history) + history.n_pending() + len(batch)
            emit(self._unseen(history, self._init_points[idx], exclude=keys))
        if len(batch) == n:
            return batch

        X, y = history.encoded()
        finite = np.isfinite(y)
        if finite.sum() < 2:
            while len(batch) < n:
                emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                                  exclude=keys))
            return batch
        # failed configs (OOM etc.) get the worst finite value (pessimism)
        y = np.where(finite, y, y[finite].min())

        gp = GaussianProcess(kind=self.kernel).fit(X, y)
        cands = self._candidates(history)
        Xs = self.space.encode_many(cands)
        post = gp.posterior(Xs)
        y_best = float(np.max(y))

        if self.acquisition == "ucb":
            acq = post.mu + self.kappa * post.sigma
        elif self.acquisition == "ei":
            z = (post.mu - y_best) / np.maximum(post.sigma, 1e-12)
            acq = (post.mu - y_best) * _norm_cdf(z) + post.sigma * _norm_pdf(z)
        elif self.acquisition == "smsego":
            # single-objective SMSego gain: how far the optimistic estimate
            # extends the best observation (epsilon-dominance guard keeps
            # pure-exploitation candidates from pinning the search)
            optimistic = post.mu + self.kappa * post.sigma
            eps = 1e-3 * max(abs(y_best), 1.0)
            gain = optimistic - (y_best + eps)
            acq = np.where(gain > 0, gain, gain * 1e-3)  # soft penalty below best
        else:
            raise ValueError(self.acquisition)

        # top-n by acquisition; stable sort so n=1 picks np.argmax's candidate
        for i in np.argsort(-acq, kind="stable"):
            if len(batch) == n:
                break
            c = cands[int(i)]
            k = self.space.key(c)
            if k in keys or (len(batch) > 0 and
                             (history.seen(c) or history.pending(c))):
                continue
            emit(dict(c))
        while len(batch) < n:  # candidate set exhausted: random fill
            emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                              exclude=keys))
        return batch
