"""Bayesian optimization engine (paper §2.2).

GP surrogate (gp.py) + acquisition maximization over a candidate set.
Acquisitions:

* ``smsego`` (paper default) — for each candidate, the optimistic estimate
  mu + c*sigma is compared against the best evaluation observed so far;
  the candidate maximizing the potential *extension* of the best value is
  selected (the single-objective S-metric-selection gain).
* ``ei``  — expected improvement (closed form).
* ``ucb`` — upper confidence bound.

The candidate set is the full grid when small, otherwise random samples
plus local perturbations of the incumbent (exploitation neighborhood).

``ask(n, ...)`` fits the surrogate once and returns the top-n candidates
by acquisition value (deduplicated, unseen), so a parallel executor can
measure a whole acquisition batch per GP fit; ``ask(1, ...)`` selects
exactly the argmax the single-point path always did.

Compile-once suggestion path
----------------------------

Under the completion-driven tuner loop every completed measurement
triggers a fresh ``ask``, so suggestion cost is on the critical path.
Three mechanisms keep it at microseconds of XLA instead of a fresh
compile (see ``gp.py`` for the shape discipline):

* the GP is **persistent** across asks and refits are **warm-started**
  from the previous hyperparameters (short refinement schedule) once the
  training set reaches ``warm_start_min_n`` rows — below that a cold fit
  is a few jitted milliseconds, the posterior is still moving fast
  enough that stale hyperparameters hurt, and the sequential suggestion
  trace stays bit-for-bit identical to the pre-compile-once engine
  (pinned by ``tests/golden/ask_tell_traces.json``); above it each Adam
  step pays a full Cholesky, which is exactly where 30 warm steps beat
  120 cold ones;
* training and candidate arrays are padded to power-of-two buckets, so
  history growth within a bucket reuses compiled executables;
* acquisition scoring + ranking runs as one fused jitted call
  (``GaussianProcess.acquisition_rank``) — the posterior never
  round-trips to host.  ``jit_acquisition=False`` selects the vectorized
  numpy scoring path instead (same ranking, no fusion).

Cost-aware acquisition (``cost_aware=True``) divides the positive
acquisition mass by a per-candidate predicted measurement cost from a
second GP fit on log ``cost_seconds`` (EI-per-second, Snoek et al.,
2012).  When the tuner reports wall-clock budget pressure via
``note_budget``, the weighting ramps in as the deadline approaches, so
the engine prefers cheap probes exactly when the remaining budget can
only afford them.  Per-ask suggestion latency and jit-cache growth are
recorded on ``ask_seconds`` / ``jit_misses`` for the bench gate.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import gp as gp_module
from repro.core.engine import Engine
from repro.core.gp import GaussianProcess
from repro.core.history import History
from repro.core.space import SearchSpace

_SQRT2 = math.sqrt(2.0)

try:  # scipy ships with jax; erf over arrays without a Python loop
    from scipy.special import erf as _erf
except ImportError:  # pragma: no cover - scipy-less fallback
    def _erf(z):
        # Abramowitz & Stegun 7.1.26 — vectorized, |err| < 1.5e-7
        z = np.asarray(z, np.float64)
        sign = np.sign(z)
        t = 1.0 / (1.0 + 0.3275911 * np.abs(z))
        poly = t * (0.254829592 + t * (-0.284496736 + t * (
            1.421413741 + t * (-1.453152027 + t * 1.061405429))))
        return sign * (1.0 - poly * np.exp(-z * z))


def _norm_cdf(z):
    return 0.5 * (1.0 + _erf(np.asarray(z) / _SQRT2))


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


class BayesOpt(Engine):
    name = "bo"

    def __init__(
        self,
        space: SearchSpace,
        seed: int = 0,
        n_init: int = 8,
        acquisition: str = "smsego",
        kappa: float = 2.0,
        max_candidates: int = 4096,
        kernel: str = "matern52",
        cost_aware: bool = False,
        jit_acquisition: bool = True,
        warm_start: bool = True,
        warm_start_min_n: int = 64,
        fidelity_feature: bool = False,
    ):
        super().__init__(space, seed)
        self.n_init = min(n_init, max(2, space.grid_size() // 2))
        self.acquisition = acquisition
        self.kappa = kappa
        self.max_candidates = max_candidates
        self.kernel = kernel
        self.cost_aware = cost_aware
        self.jit_acquisition = jit_acquisition
        self.warm_start = warm_start
        self.warm_start_min_n = warm_start_min_n
        #: multi-fidelity mode: append each observation's fidelity as an
        #: extra GP input column (candidates are scored at fidelity 1.0),
        #: so partial measurements inform the surrogate without being
        #: mistaken for exact values.  Off by default: the single-fidelity
        #: suggestion trace stays bit-for-bit identical.
        self.fidelity_feature = fidelity_feature
        self._init_points = None
        self._gp: Optional[GaussianProcess] = None
        self._cost_gp: Optional[GaussianProcess] = None
        self._grid_cache = None  # small grids: (points, encodings), immutable
        # per-ask observability (consumed by benchmarks + the CI gate)
        self.ask_seconds: List[float] = []
        self.jit_misses: List[int] = []

    def _candidates(self, history: History):
        """Return ``(cands, Xs)``: candidate points + their encodings.

        Small grids are enumerated and encoded exactly once per engine
        (the grid is immutable); each ask just slices out the unseen
        rows, keeping host-side Python work off the per-completion
        suggestion path.
        """
        if self.space.grid_size() <= self.max_candidates:
            if self._grid_cache is None:
                pts = list(self.space.enumerate())
                self._grid_cache = (pts, self.space.encode_many(pts))
            pts, enc = self._grid_cache
            idx = [i for i, p in enumerate(pts) if not history.seen(p)]
            if not idx:
                return pts, enc
            return [pts[i] for i in idx], enc[idx]
        cands = self.space.sample(self.rng, self.max_candidates // 2)
        # local neighborhood of the incumbent (exploitation half); in
        # fidelity mode the incumbent must be a full measurement — a
        # partial value's optimistic bias would center exploitation on
        # measurement noise (same guard as y_best in _ask)
        best = history.best(full_fidelity_only=self.fidelity_feature and bool(
            np.any((history.fidelities() >= 1.0)
                   & np.isfinite(history.values())))).point
        for _ in range(self.max_candidates // 2):
            cands.append(self.space.perturb(self.rng, best, radius=2))
        seen_keys = set()
        out = []
        for c in cands:
            k = self.space.key(c)
            if k not in seen_keys and not history.seen(c):
                seen_keys.add(k)
                out.append(c)
        out = out or cands
        return out, self.space.encode_many(out)

    # -- surrogate maintenance ------------------------------------------------
    def _fit_surrogate(self, X: np.ndarray, y: np.ndarray) -> GaussianProcess:
        """Refit the persistent GP, warm-starting from the previous fit.

        Warm-start policy: cold refits below ``warm_start_min_n`` rows
        (cheap under compile-once shapes, keeps the small-history
        suggestion trace bit-for-bit stable), warm refinement above
        (each Adam step pays a Cholesky there, so 30 warm steps beat
        120 cold ones).
        """
        if self._gp is None:
            self._gp = GaussianProcess(kind=self.kernel)
        params0 = (self._gp.params
                   if self.warm_start and X.shape[0] >= self.warm_start_min_n
                   else None)
        self._gp.fit(X, y, params0=params0)
        return self._gp

    def _fit_cost_model(self, X: np.ndarray,
                        history: History) -> Optional[GaussianProcess]:
        """GP over log measurement cost; None until >= 2 costs were paid."""
        if not self.cost_aware:
            return None
        costs = history.costs()
        paid = costs > 0
        if paid.sum() < 2 or float(costs[paid].std()) == 0.0:
            return None
        filled = np.where(paid, costs, costs[paid].mean())
        log_cost = np.log(np.maximum(filled, 1e-6))
        if self._cost_gp is None:
            self._cost_gp = GaussianProcess(kind=self.kernel)
        # same warm-start policy as the value GP: cold while small (the
        # cost posterior is still moving fast), warm refinement above
        params0 = (self._cost_gp.params
                   if self.warm_start and X.shape[0] >= self.warm_start_min_n
                   else None)
        self._cost_gp.fit(X, log_cost, params0=params0)
        return self._cost_gp

    def _cost_alpha(self) -> float:
        """EI-per-second exponent: full strength without budget info, else
        ramping 0 -> 1 as the wall-clock budget nears exhaustion."""
        frac = self.budget_fraction_remaining
        if frac is None:
            return 1.0
        return float(np.clip(1.0 - frac, 0.0, 1.0))

    # -- acquisition scoring --------------------------------------------------
    def _rank_numpy(self, gp: GaussianProcess, Xs: np.ndarray, y_best: float,
                    cost_gp: Optional[GaussianProcess]) -> np.ndarray:
        """Vectorized numpy scoring fallback (no host/device fusion)."""
        post = gp.posterior(Xs)
        if self.acquisition == "ucb":
            acq = post.mu + self.kappa * post.sigma
        elif self.acquisition == "ei":
            z = (post.mu - y_best) / np.maximum(post.sigma, 1e-12)
            acq = (post.mu - y_best) * _norm_cdf(z) + post.sigma * _norm_pdf(z)
        elif self.acquisition == "smsego":
            # single-objective SMSego gain: how far the optimistic estimate
            # extends the best observation (epsilon-dominance guard keeps
            # pure-exploitation candidates from pinning the search)
            optimistic = post.mu + self.kappa * post.sigma
            eps = 1e-3 * max(abs(y_best), 1.0)
            gain = optimistic - (y_best + eps)
            acq = np.where(gain > 0, gain, gain * 1e-3)  # soft penalty below best
        else:
            raise ValueError(self.acquisition)
        if cost_gp is not None:
            rel = (np.exp(cost_gp.posterior(Xs).mu)
                   / max(self.mean_cost_seconds, 1e-9))
            rel = np.clip(rel, 1e-2, 1e2) ** self._cost_alpha()
            acq = np.where(acq > 0, acq / rel, acq * rel)
        return np.argsort(-acq, kind="stable")

    def _rank(self, gp: GaussianProcess, Xs: np.ndarray, y_best: float,
              cost_gp: Optional[GaussianProcess]) -> np.ndarray:
        if not self.jit_acquisition:
            return self._rank_numpy(gp, Xs, y_best, cost_gp)
        order, _ = gp.acquisition_rank(
            Xs, self.acquisition, y_best, kappa=self.kappa,
            cost_gp=cost_gp, cost_alpha=self._cost_alpha(),
            mean_cost=self.mean_cost_seconds)
        return order

    def ask(self, n: int, history: History) -> List[Dict]:
        t0 = time.perf_counter()
        entries0 = gp_module.jit_cache_entries()
        try:
            return self._ask(n, history)
        finally:
            self.ask_seconds.append(time.perf_counter() - t0)
            self.jit_misses.append(gp_module.jit_cache_entries() - entries0)

    def _ask(self, n: int, history: History) -> List[Dict]:
        if self._init_points is None:
            self._init_points = self.space.sample_lhs(self.rng, self.n_init)
        batch: List[Dict] = []
        keys = set()

        def emit(point: Dict) -> None:
            keys.add(self.space.key(point))
            batch.append(point)

        # LHS init phase (possibly only the head of the batch)
        while (len(batch) < n
               and len(history) + history.n_pending() + len(batch) < self.n_init):
            idx = len(history) + history.n_pending() + len(batch)
            emit(self._unseen(history, self._init_points[idx], exclude=keys))
        if len(batch) == n:
            return batch

        X, y = history.encoded()
        finite = np.isfinite(y)
        if finite.sum() < 2:
            while len(batch) < n:
                emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                                  exclude=keys))
            return batch
        # failed configs (OOM etc.) get the worst finite value (pessimism)
        y = np.where(finite, y, y[finite].min())
        if self.fidelity_feature:
            # fidelity is an input feature: the GP learns how partial
            # measurements relate to full ones instead of treating a
            # cheap noisy value as ground truth
            X = np.concatenate([X, history.fidelities()[:, None]], axis=1)

        gp = self._fit_surrogate(X, y)
        cost_gp = self._fit_cost_model(X, history)
        cands, Xs = self._candidates(history)
        if self.fidelity_feature:
            # candidates are scored as full measurements
            Xs = np.concatenate([Xs, np.ones((Xs.shape[0], 1))], axis=1)
            # ... and the incumbent must be one too: a partial value's
            # optimistic bias would otherwise set a y_best no full
            # measurement can beat, collapsing the acquisition
            full = finite & (history.fidelities() >= 1.0)
            y_best = float(np.max(y[full])) if full.any() else float(np.max(y))
        else:
            y_best = float(np.max(y))
        order = self._rank(gp, Xs, y_best, cost_gp)

        # top-n by acquisition; stable sort so n=1 picks np.argmax's candidate
        for i in order:
            if len(batch) == n:
                break
            c = cands[int(i)]
            k = self.space.key(c)
            if k in keys or (len(batch) > 0 and
                             (history.seen(c) or history.pending(c))):
                continue
            emit(dict(c))
        while len(batch) < n:  # candidate set exhausted: random fill
            emit(self._unseen(history, self.space.sample(self.rng, 1)[0],
                              exclude=keys))
        return batch
