"""Tuner orchestrator (paper Fig. 4), completion-driven edition.

Algorithm-selection switch + iteration budget (paper: 50) **or**
wall-clock budget + memoized objective + checkpoint/resume.

The default loop (``loop="async"``) is a completion-driven scheduler:
the engine is asked for enough candidates to fill every free worker, the
:class:`EvaluationExecutor` measures them concurrently, and the moment
*any* evaluation completes its result is ``tell``-ed back and a single
replacement point is asked — so engines see results in completion order
(BO refreshes its candidate set per completion, the GA inserts
steady-state, Nelder-Mead reconciles speculative probes that finish
late) and no worker ever idles at a batch barrier behind one slow
configuration.  ``loop="batch"`` keeps the legacy per-batch barrier for
comparison (see ``benchmarks/perf_iterations.py --async-loop``).

``parallelism=1`` (the default) uses the serial executor and both loops
degenerate to the historical one-point-per-iteration sequence, which
reproduces the seed trace bit-for-bit for the same seed (pinned by
``tests/golden/ask_tell_traces.json``).

The wall-clock budget bounds *in-flight* work, not just the gaps between
completions: the deadline is threaded into the executor's wait machinery
(the same plumbing that enforces per-evaluation timeouts), and work
still unfinished when it passes is **abandoned** — nothing recorded,
nothing cached, the run stops on time.  When a wall-clock budget is
configured, ``parallelism=1`` automatically uses a 1-worker thread pool
instead of the serial backend, since only a pool can abandon a running
evaluation; an explicitly forced ``executor_backend="serial"`` can still
only stop *between* evaluations, never mid-measurement.

``memo_cache_path`` backs the executor's memo cache with an on-disk JSON
store (atomic writes + cross-process file locking), so a re-run or a
resumed run of the same tuning job re-evaluates nothing and multiple
hosts sharing a filesystem reuse each other's measurements.

``workers=["host:port", ...]`` (or ``executor_backend="remote"``) farms
the measurements to ``launch/worker.py`` daemons over the RPC protocol
in ``repro.tuning.remote``: the completion-driven loop sizes its
in-flight window to the fleet's registered slot total, a worker death
reinjects its in-flight measurements onto survivors (never recorded as
config failures), and every result still lands in the same memo cache —
written by *this* process, so the worker fleet needs no shared
filesystem.

``multi_fidelity=True`` layers a successive-halving rung scheduler
(ASHA; see ``repro.tuning.fidelity``) over the async loop: fresh
candidates are screened with cheap partial measurements, survivors are
promoted fidelity by fidelity, and in-flight promotions that have been
outclassed are preempted through the executor.  The budget then counts
full-measurement equivalents (sum of completed fidelities), so the
scheduler spends what the same budget of full measurements would have —
just on many more candidates.

Objectives follow the explicit evaluator protocol (``(value, meta)``;
see ``repro.tuning.objective``); plain scalar callables are adapted
automatically.  Failures (OOM, compile error, timeout) surface as
``-inf`` and are recorded, mirroring how a real measurement harness
handles a crashed configuration.
"""
from __future__ import annotations

import math
import pathlib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.bayesopt import BayesOpt
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.random_search import RandomSearch
from repro.core.space import SearchSpace
from repro.tuning.executor import EvalResult, EvaluationExecutor, PendingEval
from repro.tuning.objective import as_evaluator

ENGINES = {
    "bo": BayesOpt,
    "ga": GeneticAlgorithm,
    "nms": NelderMead,
    "random": RandomSearch,
    "exhaustive": Exhaustive,
}

LOOPS = ("async", "batch")


@dataclass
class TunerConfig:
    algorithm: str = "bo"
    budget: int = 50  # paper: tuning iterations capped at 50
    seed: int = 0
    checkpoint_path: Optional[str] = None
    engine_kwargs: dict = field(default_factory=dict)
    verbose: bool = True
    # -- parallel evaluation -------------------------------------------------
    parallelism: int = 1  # worker-pool width; 1 == historical sequential loop
    batch_size: Optional[int] = None  # batch loop: points per ask
    executor_backend: Optional[str] = None  # serial|thread|process|remote
    # (auto: serial at parallelism=1, thread above, remote when workers set)
    workers: Optional[List[str]] = None  # remote backend: host:port worker
    # daemons (launch/worker.py); parallelism becomes the fleet's slot total
    eval_timeout: Optional[float] = None  # seconds per evaluation; -inf past it
    wall_clock_budget: Optional[float] = None  # secs; unfinished work is
    # abandoned at the deadline (forces a pool backend unless overridden)
    loop: str = "async"  # async (completion-driven) | batch (legacy barrier)
    memo_cache_path: Optional[str] = None  # disk-backed cross-run memo cache
    cost_aware: bool = False  # BO: EI-per-second acquisition (prefer cheap
    # probes, ramping in as wall_clock_budget nears exhaustion)
    # -- multi-fidelity (successive halving) ---------------------------------
    multi_fidelity: bool = False  # screen candidates at partial fidelity,
    # promote survivors rung by rung (ASHA); budget then counts
    # full-measurement *equivalents* (sum of fidelities), not evaluations
    mf_eta: float = 3.0  # rung reduction factor (fidelity ratio + survivor
    # fraction 1/eta between adjacent rungs)
    mf_min_fidelity: float = 0.1  # bottom-rung fidelity floor
    mf_promote_quantile: Optional[float] = None  # per-rung survivor
    # quantile (default 1/eta)
    mf_preempt: bool = True  # kill in-flight promotions whose source rung
    # has since outclassed them (executor preempt: cancelled if unstarted,
    # recorded normally if already running)


class Tuner:
    def __init__(
        self,
        objective: Callable[[Dict], float],
        space: SearchSpace,
        config: TunerConfig = TunerConfig(),
    ):
        self.objective = as_evaluator(objective)
        self.space = space
        self.config = config
        if config.algorithm not in ENGINES:
            raise ValueError(
                f"unknown algorithm {config.algorithm!r}; one of {sorted(ENGINES)}"
            )
        if config.loop not in LOOPS:
            raise ValueError(f"unknown loop {config.loop!r}; one of {LOOPS}")
        engine_kwargs = dict(config.engine_kwargs)
        if config.cost_aware:
            if config.algorithm != "bo":
                raise ValueError(
                    "cost_aware acquisition is a BayesOpt feature "
                    f"(algorithm={config.algorithm!r})")
            engine_kwargs.setdefault("cost_aware", True)
        if config.multi_fidelity:
            if config.loop != "async":
                raise ValueError(
                    "multi_fidelity requires the completion-driven loop "
                    f"(loop={config.loop!r}): rung promotion and preemption "
                    "are decided per completion, which a batch barrier "
                    "cannot express")
            if config.algorithm == "bo":
                # partial observations enter the surrogate with a fidelity
                # feature, never as exact values
                engine_kwargs.setdefault("fidelity_feature", True)
        self.engine: Engine = ENGINES[config.algorithm](
            space, seed=config.seed, **engine_kwargs
        )
        backend = config.executor_backend
        if backend is None and config.workers:
            backend = "remote"
        if backend is None and config.wall_clock_budget is not None:
            # the serial backend cannot abandon a running evaluation, so a
            # wall-clock budget needs a pool even at parallelism=1
            backend = "thread"
        self.executor = EvaluationExecutor(
            self.objective, space,
            parallelism=config.parallelism,
            backend=backend,
            timeout=config.eval_timeout,
            cache_path=config.memo_cache_path,
            workers=config.workers,
        )
        self.history = History(space)
        self.rung_scheduler = None  # set by the multi-fidelity loop
        if config.checkpoint_path and pathlib.Path(config.checkpoint_path).exists():
            self._resume(config.checkpoint_path)

    def _resume(self, path: str) -> None:
        """Fault tolerance: reload history + replay it into the engine.

        A checkpoint only ever contains completed evaluations (points
        still in flight when the run died are excluded from
        ``History.save``), so resuming mid-stream simply re-evaluates
        whatever had not finished — or pulls it straight from the
        disk-backed memo cache if it completed after the checkpoint.

        Replay goes through ``tell`` (one call with the whole trace), not
        raw per-point ``observe``: engines with speculative batches
        (Nelder-Mead) buffer the results and consume only the points
        their state machine actually reaches, in order — feeding
        unconsumed speculative probes into ``observe`` would corrupt the
        state machine.
        """
        loaded = History.load(path, self.space)
        for ev in loaded.evals:
            self.history.add(ev.point, ev.value, ev.cost_seconds, ev.meta,
                             ev.fidelity)
        self.engine.tell([ev.point for ev in loaded.evals],
                         [ev.value for ev in loaded.evals],
                         [ev.cost_seconds for ev in loaded.evals],
                         fidelities=[ev.fidelity for ev in loaded.evals])
        if self.config.verbose and len(loaded):
            print(f"[tuner] resumed {len(loaded)} evaluations from {path}")

    # -- shared helpers ------------------------------------------------------
    def _report(self, r: EvalResult) -> None:
        if not self.config.verbose:
            return
        best = (self.history.best().value
                if any(math.isfinite(e.value) for e in self.history.evals)
                else float("nan"))
        print(
            f"[tuner:{self.engine.name}] it={len(self.history):3d} "
            f"y={r.value:.4g} best={best:.4g} "
            f"({r.cost_seconds:.1f}s) {r.point}"
        )

    def _record(self, r: EvalResult, fidelity: float = 1.0) -> None:
        """tell + append + checkpoint for one completed evaluation."""
        self.engine.tell([r.point], [r.value], [r.cost_seconds],
                         fidelities=[fidelity])
        self.history.add(r.point, r.value, r.cost_seconds, r.meta, fidelity)
        if self.config.checkpoint_path:
            self.history.save(self.config.checkpoint_path)
        self._report(r)

    def _wall_clock_exhausted(self, wall_clock: Optional[float]) -> None:
        if self.config.verbose:
            print(f"[tuner:{self.engine.name}] wall-clock budget "
                  f"({wall_clock:.1f}s) exhausted at "
                  f"{len(self.history)} evaluations")

    # -- completion-driven loop (default) ------------------------------------
    def _run_async(self, budget: int, wall_clock: Optional[float]) -> History:
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        outstanding: List[PendingEval] = []
        try:
            while len(self.history) < budget:
                if deadline is not None and time.time() >= deadline:
                    self._wall_clock_exhausted(wall_clock)
                    break
                # refill: one ask per free worker slot, the moment it frees
                # (executor.parallelism, not config: the remote backend's
                # capacity is the fleet's registered slot total)
                capacity = self.executor.parallelism - len(outstanding)
                want = min(capacity,
                           budget - len(self.history) - len(outstanding))
                asked_any = False
                if want > 0:
                    if deadline is not None:  # budget pressure -> cost-aware BO
                        self.engine.note_budget(
                            max(0.0, (deadline - time.time()) / wall_clock))
                    points = self.engine.ask(want, self.history)
                    asked_any = bool(points)
                    submitted = []
                    for p in points[:want]:
                        cached = self.history.lookup(p)
                        if cached is not None:
                            # memoized repeat query: free, told immediately
                            self._record(EvalResult(dict(p), cached.value,
                                                    0.0, {"memoized": True}))
                            continue
                        if self.history.pending(p):
                            continue  # its measurement is already in flight
                        submitted.append(p)
                    if submitted:
                        self.history.mark_inflight(submitted)
                        outstanding.extend(self.executor.submit(submitted))
                if len(self.history) >= budget:
                    break
                if not outstanding:
                    if not asked_any:
                        break  # engine has nothing left to propose
                    continue  # asks were all memo hits; go ask again
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:  # deadline passed while waiting
                    self._wall_clock_exhausted(wall_clock)
                    break
                outstanding.remove(done)
                self._record(done.result())
        finally:
            # abandoned in-flight points (wall-clock expiry / hard abort)
            # must not leave stale pending marks behind; anything still
            # marked here is by definition unmeasured (add() unmarks on
            # completion), so clearing the whole set is exact
            self.history.clear_inflight()
        return self.history

    # -- multi-fidelity successive-halving loop ------------------------------
    def _run_multi_fidelity(self, budget: int,
                            wall_clock: Optional[float]) -> History:
        """Completion-driven ASHA on top of the async machinery.

        Fresh engine candidates enter at the bottom rung (cheap partial
        measurements); completions in the top ``1/mf_eta`` of their rung
        are resubmitted at the next fidelity the moment a worker frees,
        and in-flight promotions whose source rung has since outclassed
        them are preempted (cancelled when still queued; recorded
        normally when a worker already started — exactly-once either
        way).  ``budget`` counts full-measurement *equivalents*: the sum
        of completed fidelities, so ``budget=50`` spends what 50 full
        measurements would have.

        Every completion — partial or full — lands in history with its
        fidelity and is told to the engine (BO reads the fidelity column
        as a surrogate feature; ranking engines use partial values as
        ASHA does).  ``history.best(full_fidelity_only=True)`` is the
        trustworthy incumbent.

        An objective without fidelity support cannot cheapen a
        measurement, so rungs would all cost the same and "promotion"
        would just re-measure points: the loop degenerates to the plain
        completion-driven loop instead.
        """
        from repro.tuning.fidelity import RungScheduler

        if not getattr(self.objective, "supports_fidelity", False):
            if self.config.verbose:
                print("[tuner] objective has no fidelity support; "
                      "multi_fidelity degenerates to the async loop")
            return self._run_async(budget, wall_clock)

        cfg = self.config
        sched = RungScheduler(eta=cfg.mf_eta,
                              min_fidelity=cfg.mf_min_fidelity,
                              promote_quantile=cfg.mf_promote_quantile)
        self.rung_scheduler = sched  # observability (bench rung stats)
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        outstanding: List[PendingEval] = []
        spend = 0.0  # full-measurement equivalents consumed
        # checkpoint resume: rebuild rung state (results AND promotion
        # marks — see RungScheduler.replay) and budget accounting from the
        # replayed history, so already-screened survivors stay promotable
        # exactly once and the budget is not re-spent from zero
        for e in self.history.evals:
            sched.replay(self.space.key(e.point), e.point, e.value,
                         e.fidelity)
            spend += e.fidelity

        def consume(done: PendingEval) -> None:
            nonlocal spend
            r = done.result()
            if r.meta.get("preempted"):
                return  # cancelled pre-start: nothing was measured
            rung = done.rung if done.rung is not None else 0
            # budget and history record what was *delivered*, not what the
            # rung asked for: the executor upgrades requests the evaluator
            # cannot serve partially (meta["fidelity"] / a normalized
            # pending fidelity say so) and those must be charged — and
            # trusted — as full measurements
            fid = r.meta.get("fidelity")
            if fid is None:
                fid = 1.0 if done.fidelity is None else done.fidelity
            fid = float(fid)
            spend += fid  # memo hits count too: budget is logical spend
            sched.on_result(self.space.key(done.point), done.point,
                            r.value, rung)
            self._record(r, fidelity=fid)

        try:
            while spend < budget:
                if deadline is not None and time.time() >= deadline:
                    self._wall_clock_exhausted(wall_clock)
                    break
                capacity = self.executor.parallelism - len(outstanding)
                submitted_any = False
                # promotions outrank fresh probes for free workers: a
                # survivor's next rung is the highest-value measurement
                # the ladder currently knows how to ask for
                while capacity > 0:
                    job = sched.next_promotion()
                    if job is None:
                        break
                    point, rung = job
                    pend = self.executor.submit(
                        [point], fidelity=sched.fidelity(rung), rung=rung)[0]
                    sched.on_started(self.space.key(point), point, rung)
                    outstanding.append(pend)
                    capacity -= 1
                    submitted_any = True
                if capacity > 0:
                    if deadline is not None:
                        self.engine.note_budget(
                            max(0.0, (deadline - time.time()) / wall_clock))
                    points = self.engine.ask(capacity, self.history)
                    for p in points[:capacity]:
                        if self.history.seen(p) or self.history.pending(p):
                            continue  # known at some rung / already in flight
                        pend = self.executor.submit(
                            [p], fidelity=sched.base_fidelity, rung=0)[0]
                        sched.on_started(self.space.key(p), p, 0)
                        self.history.mark_inflight([p])
                        outstanding.append(pend)
                        submitted_any = True
                # preemption scan: an in-flight promotion whose source-rung
                # value fell below the current cutoff cannot win anything
                # by finishing (the cutoff can transiently dip when the
                # survivor count increments — see RungScheduler.dominated)
                if cfg.mf_preempt:
                    for pend in list(outstanding):
                        if (pend.rung and not pend.preempted
                                and not pend.done()
                                and sched.dominated(self.space.key(pend.point),
                                                    pend.rung)):
                            if self.executor.preempt(pend) == "cancelled":
                                outstanding.remove(pend)
                                sched.on_preempted(self.space.key(pend.point),
                                                   pend.rung)
                            # "running": the worker got there first; its
                            # result arrives and is recorded normally
                if not outstanding:
                    if not submitted_any:
                        break  # engine exhausted, no promotions possible
                    continue
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:
                    self._wall_clock_exhausted(wall_clock)
                    break
                outstanding.remove(done)
                consume(done)
            # drain: promotions are event-driven, so the loop can have
            # dispatched slightly past the logical budget — those
            # measurements are paid for and must be recorded (exactly-once
            # accounting), never silently dropped.  A wall-clock deadline
            # still wins: past it, next_completed abandons as usual.
            while outstanding:
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:
                    break  # deadline: in-flight work is abandoned unrecorded
                outstanding.remove(done)
                consume(done)
        finally:
            self.history.clear_inflight()
        return self.history

    # -- legacy batch-barrier loop -------------------------------------------
    def _evaluate_batch(self, points: List[Dict],
                        deadline: Optional[float] = None) -> List[EvalResult]:
        """History-memoized repeats are free; the rest go to the executor."""
        results: List[Optional[EvalResult]] = [None] * len(points)
        miss_idx, miss_points = [], []
        for i, p in enumerate(points):
            cached = self.history.lookup(p)
            if cached is not None:  # memoized repeat query (engines may revisit)
                results[i] = EvalResult(dict(p), cached.value, 0.0,
                                        {"memoized": True})
            else:
                miss_idx.append(i)
                miss_points.append(p)
        if miss_points:
            for i, r in zip(miss_idx,
                            self.executor.evaluate(miss_points,
                                                   deadline=deadline)):
                results[i] = r
        return results

    def _run_batch(self, budget: int, wall_clock: Optional[float]) -> History:
        batch_size = self.config.batch_size or max(1, self.executor.parallelism)
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        while len(self.history) < budget:
            if deadline is not None and time.time() >= deadline:
                self._wall_clock_exhausted(wall_clock)
                break
            if deadline is not None:  # budget pressure -> cost-aware BO
                self.engine.note_budget(
                    max(0.0, (deadline - time.time()) / wall_clock))
            points = self.engine.ask(
                min(batch_size, budget - len(self.history)), self.history)
            if not points:
                break  # engine has nothing left to propose
            self.history.mark_inflight(points)
            try:
                results = self._evaluate_batch(points, deadline=deadline)
            finally:
                self.history.clear_inflight(points)
            # a None slot was abandoned at the wall-clock deadline: it was
            # never measured, so it enters neither the engine nor history
            done = [(p, r) for p, r in zip(points, results) if r is not None]
            if done:
                pts, rs = [p for p, _ in done], [r for _, r in done]
                self.engine.tell(pts, [r.value for r in rs],
                                 [r.cost_seconds for r in rs])
                self.history.add_batch(
                    pts, [r.value for r in rs],
                    [r.cost_seconds for r in rs], [r.meta for r in rs])
                if self.config.checkpoint_path:
                    self.history.save(self.config.checkpoint_path)
                if self.config.verbose:
                    for r in rs:
                        self._report(r)
        return self.history

    def run(self, budget: Optional[int] = None,
            wall_clock: Optional[float] = None) -> History:
        budget = budget if budget is not None else self.config.budget
        wall_clock = (wall_clock if wall_clock is not None
                      else self.config.wall_clock_budget)
        if (wall_clock is not None and self.executor.backend == "serial"
                and self.config.executor_backend is None):
            # a wall-clock budget supplied at run() time needs the same
            # pool fallback __init__ applies for a configured one: the
            # serial backend cannot abandon a running evaluation.  The
            # memo cache (and its disk store) carries over.
            old = self.executor
            self.executor = EvaluationExecutor(
                self.objective, self.space,
                parallelism=self.config.parallelism, backend="thread",
                timeout=self.config.eval_timeout, cache=old.cache)
            old.close()
        if self.config.multi_fidelity:
            return self._run_multi_fidelity(budget, wall_clock)
        if self.config.loop == "batch":
            return self._run_batch(budget, wall_clock)
        return self._run_async(budget, wall_clock)

    def close(self) -> None:
        self.executor.close()
