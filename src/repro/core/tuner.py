"""Tuner orchestrator (paper Fig. 4), completion-driven edition.

Algorithm-selection switch + iteration budget (paper: 50) **or**
wall-clock budget + memoized objective + checkpoint/resume.

The default loop (``loop="async"``) is a completion-driven scheduler:
the engine is asked for enough candidates to fill every free worker, the
:class:`EvaluationExecutor` measures them concurrently, and the moment
*any* evaluation completes its result is ``tell``-ed back and a single
replacement point is asked — so engines see results in completion order
(BO refreshes its candidate set per completion, the GA inserts
steady-state, Nelder-Mead reconciles speculative probes that finish
late) and no worker ever idles at a batch barrier behind one slow
configuration.  ``loop="batch"`` keeps the legacy per-batch barrier for
comparison (see ``benchmarks/perf_iterations.py --async-loop``).

``parallelism=1`` (the default) uses the serial executor and both loops
degenerate to the historical one-point-per-iteration sequence, which
reproduces the seed trace bit-for-bit for the same seed (pinned by
``tests/golden/ask_tell_traces.json``).

The wall-clock budget bounds *in-flight* work, not just the gaps between
completions: the deadline is threaded into the executor's wait machinery
(the same plumbing that enforces per-evaluation timeouts), and work
still unfinished when it passes is **abandoned** — nothing recorded,
nothing cached, the run stops on time.  When a wall-clock budget is
configured, ``parallelism=1`` automatically uses a 1-worker thread pool
instead of the serial backend, since only a pool can abandon a running
evaluation; an explicitly forced ``executor_backend="serial"`` can still
only stop *between* evaluations, never mid-measurement.

``memo_cache_path`` backs the executor's memo cache with an on-disk JSON
store (atomic writes + cross-process file locking), so a re-run or a
resumed run of the same tuning job re-evaluates nothing and multiple
hosts sharing a filesystem reuse each other's measurements.

Objectives follow the explicit evaluator protocol (``(value, meta)``;
see ``repro.tuning.objective``); plain scalar callables are adapted
automatically.  Failures (OOM, compile error, timeout) surface as
``-inf`` and are recorded, mirroring how a real measurement harness
handles a crashed configuration.
"""
from __future__ import annotations

import math
import pathlib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.bayesopt import BayesOpt
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.random_search import RandomSearch
from repro.core.space import SearchSpace
from repro.tuning.executor import EvalResult, EvaluationExecutor, PendingEval
from repro.tuning.objective import as_evaluator

ENGINES = {
    "bo": BayesOpt,
    "ga": GeneticAlgorithm,
    "nms": NelderMead,
    "random": RandomSearch,
    "exhaustive": Exhaustive,
}

LOOPS = ("async", "batch")


@dataclass
class TunerConfig:
    algorithm: str = "bo"
    budget: int = 50  # paper: tuning iterations capped at 50
    seed: int = 0
    checkpoint_path: Optional[str] = None
    engine_kwargs: dict = field(default_factory=dict)
    verbose: bool = True
    # -- parallel evaluation -------------------------------------------------
    parallelism: int = 1  # worker-pool width; 1 == historical sequential loop
    batch_size: Optional[int] = None  # batch loop: points per ask
    executor_backend: Optional[str] = None  # serial|thread|process (auto)
    eval_timeout: Optional[float] = None  # seconds per evaluation; -inf past it
    wall_clock_budget: Optional[float] = None  # secs; unfinished work is
    # abandoned at the deadline (forces a pool backend unless overridden)
    loop: str = "async"  # async (completion-driven) | batch (legacy barrier)
    memo_cache_path: Optional[str] = None  # disk-backed cross-run memo cache
    cost_aware: bool = False  # BO: EI-per-second acquisition (prefer cheap
    # probes, ramping in as wall_clock_budget nears exhaustion)


class Tuner:
    def __init__(
        self,
        objective: Callable[[Dict], float],
        space: SearchSpace,
        config: TunerConfig = TunerConfig(),
    ):
        self.objective = as_evaluator(objective)
        self.space = space
        self.config = config
        if config.algorithm not in ENGINES:
            raise ValueError(
                f"unknown algorithm {config.algorithm!r}; one of {sorted(ENGINES)}"
            )
        if config.loop not in LOOPS:
            raise ValueError(f"unknown loop {config.loop!r}; one of {LOOPS}")
        engine_kwargs = dict(config.engine_kwargs)
        if config.cost_aware:
            if config.algorithm != "bo":
                raise ValueError(
                    "cost_aware acquisition is a BayesOpt feature "
                    f"(algorithm={config.algorithm!r})")
            engine_kwargs.setdefault("cost_aware", True)
        self.engine: Engine = ENGINES[config.algorithm](
            space, seed=config.seed, **engine_kwargs
        )
        backend = config.executor_backend
        if backend is None and config.wall_clock_budget is not None:
            # the serial backend cannot abandon a running evaluation, so a
            # wall-clock budget needs a pool even at parallelism=1
            backend = "thread"
        self.executor = EvaluationExecutor(
            self.objective, space,
            parallelism=config.parallelism,
            backend=backend,
            timeout=config.eval_timeout,
            cache_path=config.memo_cache_path,
        )
        self.history = History(space)
        if config.checkpoint_path and pathlib.Path(config.checkpoint_path).exists():
            self._resume(config.checkpoint_path)

    def _resume(self, path: str) -> None:
        """Fault tolerance: reload history + replay it into the engine.

        A checkpoint only ever contains completed evaluations (points
        still in flight when the run died are excluded from
        ``History.save``), so resuming mid-stream simply re-evaluates
        whatever had not finished — or pulls it straight from the
        disk-backed memo cache if it completed after the checkpoint.

        Replay goes through ``tell`` (one call with the whole trace), not
        raw per-point ``observe``: engines with speculative batches
        (Nelder-Mead) buffer the results and consume only the points
        their state machine actually reaches, in order — feeding
        unconsumed speculative probes into ``observe`` would corrupt the
        state machine.
        """
        loaded = History.load(path, self.space)
        for ev in loaded.evals:
            self.history.add(ev.point, ev.value, ev.cost_seconds, ev.meta)
        self.engine.tell([ev.point for ev in loaded.evals],
                         [ev.value for ev in loaded.evals],
                         [ev.cost_seconds for ev in loaded.evals])
        if self.config.verbose and len(loaded):
            print(f"[tuner] resumed {len(loaded)} evaluations from {path}")

    # -- shared helpers ------------------------------------------------------
    def _report(self, r: EvalResult) -> None:
        if not self.config.verbose:
            return
        best = (self.history.best().value
                if any(math.isfinite(e.value) for e in self.history.evals)
                else float("nan"))
        print(
            f"[tuner:{self.engine.name}] it={len(self.history):3d} "
            f"y={r.value:.4g} best={best:.4g} "
            f"({r.cost_seconds:.1f}s) {r.point}"
        )

    def _record(self, r: EvalResult) -> None:
        """tell + append + checkpoint for one completed evaluation."""
        self.engine.tell([r.point], [r.value], [r.cost_seconds])
        self.history.add(r.point, r.value, r.cost_seconds, r.meta)
        if self.config.checkpoint_path:
            self.history.save(self.config.checkpoint_path)
        self._report(r)

    def _wall_clock_exhausted(self, wall_clock: Optional[float]) -> None:
        if self.config.verbose:
            print(f"[tuner:{self.engine.name}] wall-clock budget "
                  f"({wall_clock:.1f}s) exhausted at "
                  f"{len(self.history)} evaluations")

    # -- completion-driven loop (default) ------------------------------------
    def _run_async(self, budget: int, wall_clock: Optional[float]) -> History:
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        outstanding: List[PendingEval] = []
        try:
            while len(self.history) < budget:
                if deadline is not None and time.time() >= deadline:
                    self._wall_clock_exhausted(wall_clock)
                    break
                # refill: one ask per free worker slot, the moment it frees
                capacity = self.config.parallelism - len(outstanding)
                want = min(capacity,
                           budget - len(self.history) - len(outstanding))
                asked_any = False
                if want > 0:
                    if deadline is not None:  # budget pressure -> cost-aware BO
                        self.engine.note_budget(
                            max(0.0, (deadline - time.time()) / wall_clock))
                    points = self.engine.ask(want, self.history)
                    asked_any = bool(points)
                    submitted = []
                    for p in points[:want]:
                        cached = self.history.lookup(p)
                        if cached is not None:
                            # memoized repeat query: free, told immediately
                            self._record(EvalResult(dict(p), cached.value,
                                                    0.0, {"memoized": True}))
                            continue
                        if self.history.pending(p):
                            continue  # its measurement is already in flight
                        submitted.append(p)
                    if submitted:
                        self.history.mark_inflight(submitted)
                        outstanding.extend(self.executor.submit(submitted))
                if len(self.history) >= budget:
                    break
                if not outstanding:
                    if not asked_any:
                        break  # engine has nothing left to propose
                    continue  # asks were all memo hits; go ask again
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:  # deadline passed while waiting
                    self._wall_clock_exhausted(wall_clock)
                    break
                outstanding.remove(done)
                self._record(done.result())
        finally:
            # abandoned in-flight points (wall-clock expiry / hard abort)
            # must not leave stale pending marks behind; anything still
            # marked here is by definition unmeasured (add() unmarks on
            # completion), so clearing the whole set is exact
            self.history.clear_inflight()
        return self.history

    # -- legacy batch-barrier loop -------------------------------------------
    def _evaluate_batch(self, points: List[Dict],
                        deadline: Optional[float] = None) -> List[EvalResult]:
        """History-memoized repeats are free; the rest go to the executor."""
        results: List[Optional[EvalResult]] = [None] * len(points)
        miss_idx, miss_points = [], []
        for i, p in enumerate(points):
            cached = self.history.lookup(p)
            if cached is not None:  # memoized repeat query (engines may revisit)
                results[i] = EvalResult(dict(p), cached.value, 0.0,
                                        {"memoized": True})
            else:
                miss_idx.append(i)
                miss_points.append(p)
        if miss_points:
            for i, r in zip(miss_idx,
                            self.executor.evaluate(miss_points,
                                                   deadline=deadline)):
                results[i] = r
        return results

    def _run_batch(self, budget: int, wall_clock: Optional[float]) -> History:
        batch_size = self.config.batch_size or max(1, self.config.parallelism)
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        while len(self.history) < budget:
            if deadline is not None and time.time() >= deadline:
                self._wall_clock_exhausted(wall_clock)
                break
            if deadline is not None:  # budget pressure -> cost-aware BO
                self.engine.note_budget(
                    max(0.0, (deadline - time.time()) / wall_clock))
            points = self.engine.ask(
                min(batch_size, budget - len(self.history)), self.history)
            if not points:
                break  # engine has nothing left to propose
            self.history.mark_inflight(points)
            try:
                results = self._evaluate_batch(points, deadline=deadline)
            finally:
                self.history.clear_inflight(points)
            # a None slot was abandoned at the wall-clock deadline: it was
            # never measured, so it enters neither the engine nor history
            done = [(p, r) for p, r in zip(points, results) if r is not None]
            if done:
                pts, rs = [p for p, _ in done], [r for _, r in done]
                self.engine.tell(pts, [r.value for r in rs],
                                 [r.cost_seconds for r in rs])
                self.history.add_batch(
                    pts, [r.value for r in rs],
                    [r.cost_seconds for r in rs], [r.meta for r in rs])
                if self.config.checkpoint_path:
                    self.history.save(self.config.checkpoint_path)
                if self.config.verbose:
                    for r in rs:
                        self._report(r)
        return self.history

    def run(self, budget: Optional[int] = None,
            wall_clock: Optional[float] = None) -> History:
        budget = budget if budget is not None else self.config.budget
        wall_clock = (wall_clock if wall_clock is not None
                      else self.config.wall_clock_budget)
        if (wall_clock is not None and self.executor.backend == "serial"
                and self.config.executor_backend is None):
            # a wall-clock budget supplied at run() time needs the same
            # pool fallback __init__ applies for a configured one: the
            # serial backend cannot abandon a running evaluation.  The
            # memo cache (and its disk store) carries over.
            old = self.executor
            self.executor = EvaluationExecutor(
                self.objective, self.space,
                parallelism=self.config.parallelism, backend="thread",
                timeout=self.config.eval_timeout, cache=old.cache)
            old.close()
        if self.config.loop == "batch":
            return self._run_batch(budget, wall_clock)
        return self._run_async(budget, wall_clock)

    def close(self) -> None:
        self.executor.close()
